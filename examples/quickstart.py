"""Quickstart: the paper's pipeline end-to-end in ~60 lines.

1. binarise a 3x3 conv kernel -> 9-bit bit sequences (paper Fig. 2)
2. analyse sequence frequencies (Table II)
3. Hamming-1 clustering + simplified 4-node Huffman coding (Table V)
4. run the conv with weights decoded INSIDE the Pallas kernel and check it
   against the uncompressed path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, compression, frequency
from repro.kernels import ops, ref

rng = np.random.default_rng(0)

# --- a "trained-looking" binary kernel: skewed sequence distribution ------
hist = frequency.synthetic_histogram((0.46, 0.24, 0.23, 0.05), 64 * 64, rng)
seqs = np.repeat(np.arange(512), hist)[: 64 * 64]
rng.shuffle(seqs)
w_bits = bitpack.sequences_to_kernel(seqs.reshape(64, 64).astype(np.uint16))
print(f"kernel: Cout=64 Cin=64 3x3  ({w_bits.size} binary weights)")

# --- frequency analysis (paper Table II) ----------------------------------
h = frequency.sequence_histogram(bitpack.kernel_to_sequences(w_bits))
print(f"top-16 share {frequency.top_k_share(h, 16):.1%}   "
      f"top-64 {frequency.top_k_share(h, 64):.1%}   "
      f"top-256 {frequency.top_k_share(h, 256):.1%}")

# --- compression (paper Table V) -------------------------------------------
ct_enc = compression.compress_conv3x3(w_bits, cluster=False)
ct_cl = compression.compress_conv3x3(w_bits, cluster=True)
print(f"compression ratio: encoding {ct_enc.ratio_stream():.3f}x, "
      f"+clustering {ct_cl.ratio_stream():.3f}x "
      f"(paper: 1.18-1.25 / 1.30-1.36)")

# --- fused decode + xnor/popcount conv -------------------------------------
x = rng.standard_normal((2, 8, 8, 64)).astype(np.float32)
words, tables, meta = ops.prepare_compressed_conv(w_bits, cluster=False)
y_compressed = ops.compressed_binary_conv3x3(
    jnp.asarray(x), words, tables, cin=64, cout=64)
y_reference = ref.binary_conv3x3(
    jnp.asarray(x), jnp.asarray(w_bits.astype(np.float32) * 2 - 1))
np.testing.assert_array_equal(np.asarray(y_compressed),
                              np.asarray(y_reference))
print("fused decode+conv kernel == reference BNN conv  [OK]")
print(f"storage (stream layout): {ct_cl.ratio_stream():.3f}x fewer bits; "
      f"kernel weight-stream (tiled, C=8): {meta['ratio_tiled']:.3f}x — "
      "small Cout kernels don't amortise per-tile padding; see "
      "EXPERIMENTS.md §Perf K2 for the C=64 layout reaching 1.20x")
