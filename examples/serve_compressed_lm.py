"""Paper technique -> LM serving (beyond-paper integration, DESIGN.md §5).

Binarises the MLP weights of a tiny LM (BNN mode), compresses them with the
simplified Huffman coder, and serves batched requests with the weights
decoded inside the fused Pallas kernel.  Reports the weight-streaming byte
reduction — the decode-cell memory-roofline win measured in EXPERIMENTS.md
§Perf (mixtral-8x22b decode_32k).

Run:  PYTHONPATH=src python examples/serve_compressed_lm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression
from repro.kernels import ops

rng = np.random.default_rng(0)

D, F, BATCH, CODES = 288, 1024, 8, 64

# trained BNN weights develop sign structure (the paper's C1 observation);
# rows sharing a handful of sign motifs + sparse noise reproduce it
motifs = rng.standard_normal((4, D)).astype(np.float32)
sel = rng.integers(0, 4, F)
sign = rng.choice([-1.0, 1.0], F)[:, None]
base = motifs[sel] * sign
base += 0.08 * np.abs(base).mean() * rng.standard_normal((F, D))
w_bits = (base >= 0).astype(np.uint8)

words, tables, meta = ops.prepare_compressed_gemm(w_bits, cluster=True,
                                                  codes=CODES)
packed_bytes = F * (-(-D // 288) * 288 // 32) * 4
comp_bytes = int(np.asarray(words).size * 4)
print(f"MLP up-projection {F}x{D}:")
print(f"  packed 1-bit bytes      : {packed_bytes}")
print(f"  compressed tiled bytes  : {comp_bytes} "
      f"({packed_bytes / comp_bytes:.3f}x fewer)")
print(f"  stream-layout ratio     : {meta['ratio_stream']:.3f}x")

# batched "requests": sign activations through the compressed layer
x = rng.standard_normal((BATCH, D)).astype(np.float32)
y = ops.compressed_binary_matmul(
    jnp.asarray(x), words, tables, k_true=D, n_true=F, codes=CODES)

# cross-check vs the uncompressed packed kernel on the clustered weights
fc = compression.compress_gemm_fused(w_bits, cluster=True,
                                     codes_per_sub=CODES)
w_rec = compression.decompress_fused(fc).astype(np.float32) * 2 - 1
y_ref = np.asarray(jnp.where(jnp.asarray(x) >= 0, 1.0, -1.0)
                   @ jnp.asarray(w_rec).T)
np.testing.assert_array_equal(np.asarray(y), y_ref)
print(f"  served {BATCH} requests through the fused decode+GEMM kernel; "
      "outputs match the reference  [OK]")
