"""Paper technique -> LM serving through the runtime (DESIGN.md §5).

Binarises the MLP weights of a tiny LM, registers them with the runtime
WeightStore (compressed varlen stream layout), and serves batched requests
two ways from the *same* store:

  1. fused path  — weights Huffman-decoded inside the Pallas decode+GEMM
     kernel (``ops.compressed_binary_matmul``), operands routed through
     ``WeightStore.fused_operands``;
  2. cached path — decoded tiles served from the DecodeTileCache and
     reconstructed to sign * alpha weights (``WeightStore.materialize``).

Both must agree bit-exactly, and the cache stats show the paper's reuse
story: after the first step, tiles are hits, not re-decodes.  A final
section constrains the cache below the working set and compares the three
eviction policies (LRU / LFU / FrequencyWeighted seeded from the §III-A
occurrence counts) on the same serving loop.

A final section serves a reduced LM end-to-end through the Scheduler with
chunked prefill + paged KV lanes (``--prefill-chunk`` / ``--kv-page-size``)
and asserts the tokens match the monolithic configuration — then flips the
attention backend to ``pallas_paged`` (the in-kernel paged decode
attention) and asserts the tokens *still* match while the per-step KV
gather/scatter byte counter reads exactly zero.

Run:  PYTHONPATH=src python examples/serve_compressed_lm.py
      PYTHONPATH=src python examples/serve_compressed_lm.py \
          --prefill-chunk 4 --kv-page-size 8
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.runtime import (DecodeTileCache, FrequencyWeightedPolicy,
                           WeightStore)

ap = argparse.ArgumentParser()
ap.add_argument("--prefill-chunk", type=int, default=4,
                help="prompt chunk size for the scheduler section")
ap.add_argument("--kv-page-size", type=int, default=8,
                help="KV page size for the scheduler section")
args = ap.parse_args()

rng = np.random.default_rng(0)

D, F, BATCH, STEPS = 288, 1024, 8, 16

# trained BNN weights develop sign structure (the paper's C1 observation);
# rows sharing a handful of sign motifs + sparse noise reproduce it
motifs = rng.standard_normal((4, D)).astype(np.float32)
sel = rng.integers(0, 4, F)
sign = rng.choice([-1.0, 1.0], F)[:, None]
base = motifs[sel] * sign
base += 0.08 * np.abs(base).mean() * rng.standard_normal((F, D))
w = base.T.astype(np.float32)               # (D, F): d_in x d_out layout

# -- register with the runtime store (stream layout; tiled lazily) ----------
store = WeightStore(DecodeTileCache())
params = {"mlp": {"up": w}}
report = store.register_model("lm", params,
                              select=lambda p, nd: p.endswith("mlp/up"))
print(f"MLP up-projection {F}x{D}:")
print(f"  packed 1-bit bytes      : {report['packed_bytes']}")
print(f"  compressed stream bytes : {report['stream_bytes']} "
      f"({report['ratio_stream']:.3f}x)")

# -- fused path: decode inside the Pallas kernel, operands from the store ---
words, tables, meta = store.fused_operands("lm", "mlp/up")
x = rng.standard_normal((BATCH, D)).astype(np.float32)
y_fused = ops.compressed_binary_matmul(
    jnp.asarray(x), words, tables, k_true=meta["k_true"],
    n_true=meta["n_true"], codes=meta["codes"])

# -- cached path: decode-tile cache -> reconstructed sign weights -----------
for step in range(STEPS):                   # decode steps reuse the tiles
    served = store.materialize("lm")
w_rec = np.asarray(served["mlp"]["up"])     # (D, F) sign * alpha
alpha = np.asarray(meta["scale"])           # (F,) per-output-channel scale
y_cached = np.asarray(jnp.where(jnp.asarray(x) >= 0, 1.0, -1.0)
                      @ (jnp.asarray(w_rec) / alpha[None, :]))

np.testing.assert_array_equal(np.asarray(y_fused).astype(np.float32),
                              y_cached)
st = store.cache.stats()
print(f"  served {BATCH} requests x {STEPS} steps; fused kernel == "
      "cached-tile reconstruction  [OK]")
print(f"  decode-tile cache       : {st['hits']} hits / {st['misses']} "
      f"misses, hit-rate {st['hit_rate'] * 100:.1f}%")
print(f"  compressed bytes streamed {st['bytes_streamed']}, "
      f"avoided {st['bytes_avoided']}")

# -- eviction policies under pressure: same loop, capacity < working set ----
# The store seeds each tile's share of the skewed sequence-occurrence mass
# (paper §III-A) into the cache, so the FrequencyWeighted policy knows the
# hot tiles before any access history exists.  The decode loop is a pure
# cyclic scan (every step touches every tile), the regime where recency
# carries no signal: configure the policy with a long count half-life so
# the static prior decides victims, the paper's C1 pinning.
working_set = store.decoded_bytes("lm")
print(f"\n  policies at 50% of the {working_set // 1024} KiB working set "
      f"({STEPS} steps):")
policies = {"lru": "lru", "lfu": "lfu",
            "freq": FrequencyWeightedPolicy(prior_weight=4.0,
                                            half_life=1e6)}
for policy_name, policy in policies.items():
    cache = DecodeTileCache(working_set // 2, policy=policy)
    pstore = WeightStore(cache)
    pstore.register_model("lm", params,
                          select=lambda p, nd: p.endswith("mlp/up"))
    for step in range(STEPS):
        pstore.materialize("lm")
    pst = cache.stats()
    print(f"    {policy_name:>4}: hit-rate {pst['hit_rate'] * 100:5.1f}%  "
          f"evictions {pst['evictions']:4d}  "
          f"streamed {pst['bytes_streamed']}")

# -- chunked prefill + paged KV through the scheduler -----------------------
# The same compression pipeline serving a (reduced) LM end-to-end: prompts
# are split into --prefill-chunk token chunks interleaved with decode steps,
# and KV lanes are backed by --kv-page-size token pages allocated on
# demand.  Both knobs are pure scheduling: the generated tokens must equal
# the monolithic configuration's, which this section asserts.
import jax                                                          # noqa: E402

from repro.configs.base import get_config                           # noqa: E402
from repro.models.api import get_model                              # noqa: E402
from repro.runtime import Scheduler, ServeEngine                    # noqa: E402

cfg = get_config("minitron-8b").scaled(
    dtype="float32", vocab_size=128, num_layers=2, scan_repeats=2,
    d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)
lm_params = jax.tree_util.tree_map(
    np.asarray, get_model(cfg).init_params(cfg, jax.random.PRNGKey(0)))
reqs = [(rng.integers(0, cfg.vocab_size, L), g)
        for L, g in [(11, 4), (3, 6), (9, 3), (5, 5)]]


def serve_tokens(**kw):
    engine = ServeEngine(cfg, lm_params, compress=True)
    sched = Scheduler(engine, batch_size=2, buckets=(16,), **kw)
    rids = [sched.submit(p, g).rid for p, g in reqs]
    done = {r.rid: r for r in sched.run()}
    return [tuple(done[rid].generated) for rid in rids], engine.metrics


mono_toks, _ = serve_tokens()
chunk_toks, m = serve_tokens(prefill_chunk=args.prefill_chunk,
                             kv_page_size=args.kv_page_size)
assert mono_toks == chunk_toks
print(f"\n  scheduler: chunked prefill (chunk {args.prefill_chunk}) + "
      f"paged KV (page {args.kv_page_size}) == monolithic  [OK]")
print(f"  {m.prefill_chunks} prefill chunks, page pool {m.pages_total}, "
      f"mean page occupancy {m.page_occupancy() * 100:.0f}%")

# -- attention backend seam: in-kernel paged decode attention ---------------
# Same pages, different reader: instead of gathering every slot's pages
# into a contiguous view each decode step (two full cache copies), the
# pallas_paged backend hands the page pool + page tables to a Pallas
# kernel that walks the table in-kernel.  Tokens must not change, and the
# hot-path copy counter must read exactly zero.
kernel_toks, mk = serve_tokens(kv_page_size=args.kv_page_size,
                               attn_backend="pallas_paged")
assert mono_toks == kernel_toks
assert mk.kv_gather_bytes == 0
print(f"  attn backend pallas_paged == gathered  [OK]  "
      f"(0 KV bytes gathered on the decode path, "
      f"{mk.kv_gather_bytes_avoided} avoided)")

# -- mixed-step: prefill chunks + decode tokens, one paged invocation -------
# Chunked prefill under pallas_paged collapses the scheduler's two
# execution paths into one: every iteration, prefilling slots contribute a
# prompt chunk and active slots a decode token to a single ragged batched
# trace whose K/V lands straight in the page pools — no standalone prefill
# cache, no install copy.  Tokens must still match the monolithic
# configuration, and *both* KV gather counters must read exactly zero.
mixed_toks, mm = serve_tokens(prefill_chunk=args.prefill_chunk,
                              kv_page_size=args.kv_page_size,
                              attn_backend="pallas_paged")
assert mono_toks == mixed_toks
assert mm.kv_gather_bytes == 0
assert mm.kv_prefill_gather_bytes == 0
print(f"  mixed-step (chunked prefill in-kernel) == monolithic  [OK]  "
      f"(0 KV bytes gathered on the prefill AND decode paths, "
      f"{mm.kv_prefill_gather_bytes_avoided} install bytes avoided)")

# -- observability: lifecycle trace + histograms + Prometheus export --------
# The same serve with telemetry on: every request gets a span tree
# (queued -> admitted -> prefill chunks -> decode -> retired) in a
# Chrome-trace-ready recorder, latencies land in log-bucket histograms,
# and every counter renders as Prometheus text.  Telemetry observes and
# never steers: tokens must be identical to every run above.
from repro.runtime import Telemetry, parse_prom                     # noqa: E402

tel = Telemetry(trace=True)
engine = ServeEngine(cfg, lm_params, compress=True, telemetry=tel)
sched = Scheduler(engine, batch_size=2, buckets=(16,),
                  prefill_chunk=args.prefill_chunk,
                  kv_page_size=args.kv_page_size)
rids = [sched.submit(p, g).rid for p, g in reqs]
done = {r.rid: r for r in sched.run()}
assert [tuple(done[rid].generated) for rid in rids] == mono_toks
spans = [e for e in tel.tracer.chrome()["traceEvents"]
         if e.get("ph") == "X" and e["name"] == "request"]
assert len(spans) == len(reqs)
samples = parse_prom(engine.render_prom())
mt = engine.metrics
print(f"\n  telemetry: {len(spans)} request span trees, "
      f"{len(samples)} prometheus samples, tokens unchanged  [OK]")
print(f"  ttft p50 {mt.ttft_hist.percentile(50) * 1000:.0f} ms, "
      f"p99 {mt.ttft_hist.percentile(99) * 1000:.0f} ms; "
      f"phases timed: {sorted(tel.phases)}")
