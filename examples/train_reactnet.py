"""End-to-end driver (deliverable b): train a ReActNet BNN on the synthetic
image task, compress the trained kernels, and validate the compressed model.

This is the paper's full workflow: train (fp latent weights + STE) ->
offline frequency analysis -> clustering + Huffman -> deploy with the fused
decode kernels -> measure accuracy drop of clustering.

Run:  PYTHONPATH=src python examples/train_reactnet.py [--steps 150]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core import bitpack, compression, frequency
from repro.data.pipeline import SyntheticImages
from repro.models import reactnet as rn
from repro.train import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        rn.CONFIG, width=32, num_classes=10, image_size=32,
        blocks=((2, 1), (1, 2), (2, 2), (1, 1)))
    params = rn.init_params(cfg, jax.random.PRNGKey(0))
    oc = opt.OptConfig(lr=2e-2, warmup_steps=10, total_steps=args.steps,
                       weight_decay=1e-4, clip_latent=1.5)
    state = opt.init_state(params)
    data = SyntheticImages(10, 32, 32, args.batch)

    @jax.jit
    def step_fn(params, state, images, labels):
        loss, grads = jax.value_and_grad(
            lambda p: rn.loss_fn(cfg, p, {"images": images,
                                          "labels": labels}))(params)
        params, state, m = opt.apply_updates(params, grads, state, oc)
        return params, state, loss

    for i in range(args.steps):
        b = data.batch(i)
        params, state, loss = step_fn(params, state,
                                      jnp.asarray(b["images"]),
                                      jnp.asarray(b["labels"]))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}")

    # --- accuracy of the three deployment paths ---------------------------
    test = data.batch(10_001)
    imgs, labels = jnp.asarray(test["images"]), test["labels"]

    def acc(logits):
        return float((np.argmax(np.asarray(logits), -1) == labels).mean())

    a_ste = acc(rn.forward(cfg, params, imgs))
    comp_nc = rn.prepare_compressed(params, cluster=False)
    comp_cl = rn.prepare_compressed(params, cluster=True)
    cfg_c = dataclasses.replace(cfg, conv_mode="compressed")
    a_comp = acc(rn.forward(cfg_c, params, imgs, compressed=comp_nc))
    a_clus = acc(rn.forward(cfg_c, params, imgs, compressed=comp_cl))
    print(f"accuracy  float-sign: {a_ste:.3f}   compressed: {a_comp:.3f}   "
          f"compressed+clustered: {a_clus:.3f}")
    assert abs(a_ste - a_comp) < 1e-6, "lossless path must match exactly"

    # --- compression report (paper Table V / model ratio) ------------------
    bits = rn.binary_weight_bits(params)
    w3 = {k: v for k, v in bits.items() if k.endswith("w3")}
    _, rep = compression.compress_model(w3, fp_bits=rn.fp_bits(cfg, params))
    print(f"binary-kernel ratio {rep.binary_ratio:.3f}x   "
          f"model ratio {rep.model_ratio:.3f}x")
    for name, w in list(w3.items())[:2]:
        h = frequency.sequence_histogram(bitpack.kernel_to_sequences(w))
        print(f"  {name}: top-64 share {frequency.top_k_share(h, 64):.1%}")

    if args.ckpt_dir:
        ckpt.save({"params": params}, args.ckpt_dir, args.steps,
                  compress_binary=True)
        print(f"compressed checkpoint written to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
