"""Execute the runnable snippets in the docs so they cannot rot.

Fenced code blocks whose info string carries the ``docs-test`` tag, e.g.

    ```bash docs-test
    PYTHONPATH=src python -m repro.launch.serve --scale tiny --gen 4
    ```

are extracted and executed from the repository root (``bash -euo
pipefail`` for bash blocks, the current interpreter with ``PYTHONPATH=src``
for python blocks).  Untagged blocks — install commands, full-scale runs,
illustrative fragments — are skipped.  A documented file with *zero*
tagged blocks fails the check: docs with nothing executable are docs
nothing defends.

Run:  python tools/check_docs.py README.md docs/ARCHITECTURE.md
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
import tempfile

FENCE = re.compile(r"^```(\w+)([^\n`]*)$")
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def extract(path: pathlib.Path) -> list[tuple[str, int, str]]:
    """-> [(language, first line number, source)] for docs-test blocks."""
    blocks = []
    lang, start, buf = None, 0, []
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if lang is None:
            m = FENCE.match(line.strip())
            if m and "docs-test" in m.group(2):
                lang, start, buf = m.group(1), i, []
        elif line.strip() == "```":
            blocks.append((lang, start, "\n".join(buf) + "\n"))
            lang = None
        else:
            buf.append(line)
    if lang is not None:
        raise SystemExit(f"{path}: unterminated ```{lang} block at "
                         f"line {start}")
    return blocks


def run_block(lang: str, src: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if lang == "bash":
        return subprocess.run(["bash", "-euo", "pipefail", "-c", src],
                              cwd=REPO_ROOT, env=env, capture_output=True,
                              text=True)
    if lang == "python":
        with tempfile.NamedTemporaryFile("w", suffix=".py",
                                         delete=False) as f:
            f.write(src)
        try:
            return subprocess.run([sys.executable, f.name], cwd=REPO_ROOT,
                                  env=env, capture_output=True, text=True)
        finally:
            os.unlink(f.name)
    raise SystemExit(f"docs-test block with unsupported language {lang!r}")


def main(paths: list[str]) -> int:
    if not paths:
        raise SystemExit("usage: check_docs.py FILE.md [FILE.md ...]")
    failed = 0
    for name in paths:
        path = REPO_ROOT / name
        blocks = extract(path)
        if not blocks:
            print(f"FAIL {name}: no ``docs-test`` blocks — nothing "
                  "defends this file against rot")
            failed += 1
            continue
        for lang, line, src in blocks:
            proc = run_block(lang, src)
            status = "ok  " if proc.returncode == 0 else "FAIL"
            print(f"{status} {name}:{line} ({lang}, {len(src.splitlines())} "
                  "lines)")
            if proc.returncode != 0:
                failed += 1
                sys.stdout.write(proc.stdout[-2000:])
                sys.stderr.write(proc.stderr[-4000:])
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
