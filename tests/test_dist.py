"""Distribution-layer tests: sharding rules, HLO census, safe specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.hlo_census import HloCensus
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Just enough mesh for param_spec unit tests (16x16 production shape)."""

    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


@pytest.fixture
def mesh16():
    return FakeMesh({"data": 16, "model": 16})


class TestParamSpecs:
    def test_embed_vocab_sharded(self, mesh16):
        assert shd.param_spec("embed", (256000, 2304), mesh16) == \
            P("model", None)

    def test_embed_odd_vocab_replicated(self, mesh16):
        assert shd.param_spec("embed", (50280, 1536), mesh16) == \
            P(None, None)

    def test_projections(self, mesh16):
        assert shd.param_spec("scan/b0/attn/wq", (2304, 2048), mesh16) == \
            P(None, "model")
        assert shd.param_spec("scan/b0/attn/wo", (2048, 2304), mesh16) == \
            P("model", None)
        assert shd.param_spec("scan/b0/mlp/up", (2304, 9216), mesh16) == \
            P(None, "model")
        assert shd.param_spec("scan/b0/mlp/down", (9216, 2304), mesh16) == \
            P("model", None)

    def test_moe_expert_parallel(self, mesh16):
        # 160 experts divide 16 -> EP on the expert axis
        assert shd.param_spec("moe/w_gate", (160, 5120, 1536), mesh16) == \
            P("model", None, None)
        # 8 experts don't -> per-expert TP on d_ff
        assert shd.param_spec("moe/w_gate", (8, 6144, 16384), mesh16) == \
            P(None, None, "model")
        assert shd.param_spec("moe/w_down", (8, 16384, 6144), mesh16) == \
            P(None, "model", None)

    def test_fsdp_adds_dp_axis(self, mesh16):
        spec = shd.param_spec("scan/b0/attn/wq", (2304, 2048), mesh16,
                              fsdp=True)
        assert spec == P(("data",), "model")

    def test_norms_replicated(self, mesh16):
        assert shd.param_spec("scan/b0/ln1", (2304,), mesh16) == P(None)


class TestSafeSpec:
    def test_drops_nondivisible(self):
        mesh = make_host_mesh()
        spec = shd.safe_spec(mesh, (1, 1, 51866), "batch", None, "model")
        # single CPU device: batch axis size 1 divides everything; model=1
        assert isinstance(spec, P)

    def test_constrain_noop_off_mesh(self):
        x = jnp.ones((4, 4))
        assert shd.constrain(x, "batch", None) is x


class TestHloCensus:
    def test_scan_trip_weighting(self):
        a = jnp.zeros((128, 128), jnp.float32)

        def scanned(a):
            def body(x, _):
                return x @ a, None
            return jax.lax.scan(body, a, None, length=5)[0]

        hlo = jax.jit(scanned).lower(a).compile().as_text()
        c = HloCensus(hlo)
        np.testing.assert_allclose(c.flops(), 5 * 2 * 128 ** 3, rtol=0.01)

    def test_nested_scan(self):
        a = jnp.zeros((64, 64), jnp.float32)

        def nested(a):
            def inner(x, _):
                return x @ a, None

            def outer(x, _):
                return jax.lax.scan(inner, x, None, length=3)[0], None

            return jax.lax.scan(outer, a, None, length=4)[0]

        hlo = jax.jit(nested).lower(a).compile().as_text()
        c = HloCensus(hlo)
        np.testing.assert_allclose(c.flops(), 12 * 2 * 64 ** 3, rtol=0.01)

    def test_collectives_counted(self):
        mesh = make_host_mesh()
        if mesh.devices.size < 2:
            pytest.skip("single device: no collectives emitted")

    def test_hbm_modes_ordered(self):
        a = jnp.zeros((256, 256), jnp.float32)
        hlo = jax.jit(lambda x: jnp.tanh(x @ x) + 1.0).lower(a) \
            .compile().as_text()
        c = HloCensus(hlo)
        assert c.hbm_bytes("tpu") <= c.hbm_bytes("cpu")


class TestBatchShardings:
    def test_batch_of_one_replicates(self):
        mesh = make_host_mesh()
        sds = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32),
               "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        out = shd.batch_shardings(sds, mesh)
        assert out["pos"].spec == P()
