"""Unified mixed-step execution: prefill chunks and decode tokens through
one paged-attention invocation.

The ``pallas_paged`` + ``prefill_chunk`` combination must be
token-identical to the gathered oracle (the plain monolithic-prefill
serving path) across archs (plain GQA / rolling-window gemma2 / MLA
deepseek), chunk sizes {1, 3, page_size, > page_size}, and page sizes
{1, 4, odd} — and its hot loop must move **zero** KV gather/scatter
bytes on the prefill *and* decode paths (no standalone prefill cache, no
install copy: chunks write straight into the page pools).  The kernel's
ragged multi-token form is additionally checked against a pure-numpy
oracle on random page tables.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_mixed_attention
from repro.runtime import Scheduler
from tests.harness import MIXED, make_engine, mixed_requests
from tests.harness import run_trace as serve

pytestmark = pytest.mark.pallas   # CI kernels-interpret job runs these


# ---------------------------------------------------------------------------
# ragged kernel vs numpy oracle
# ---------------------------------------------------------------------------

class TestRaggedKernel:
    @pytest.mark.parametrize("window,q_block", [(0, 0), (4, 0), (0, 2)])
    def test_mixed_block_vs_dense_oracle(self, window, q_block):
        """Chunk rows, a decode row, and an empty (free-lane) row in one
        block; every real token must match dense masked attention at its
        absolute position, padding must stay finite."""
        rng = np.random.default_rng(0)
        s_n, qn, h, kh, d, page, pps = 3, 4, 4, 2, 8, 3, 4
        n_pages = s_n * pps + 2
        k_pages = rng.standard_normal(
            (n_pages, page, kh, d)).astype(np.float32)
        v_pages = rng.standard_normal(
            (n_pages, page, kh, d)).astype(np.float32)
        ids = list(range(1, n_pages))
        rng.shuffle(ids)
        it = iter(ids)
        lengths = np.array([7, 1, 10], np.int32)   # incl. this block
        q_lens = np.array([3, 1, 0], np.int32)     # chunk, decode, free
        table = np.zeros((s_n, pps), np.int32)
        for i in range(s_n):
            for j in range(-(-int(lengths[i]) // page)):
                table[i, j] = next(it)
        q = rng.standard_normal((s_n, qn, h, d)).astype(np.float32)

        out = np.asarray(paged_mixed_attention(
            jnp.asarray(q) * d ** -0.5, jnp.asarray(k_pages),
            jnp.asarray(v_pages), jnp.asarray(table),
            jnp.asarray(lengths), jnp.asarray(q_lens),
            window=window, q_block=q_block, interpret=True))
        assert np.isfinite(out).all()

        smax = pps * page
        for s in range(s_n):
            kv = k_pages[table[s]].reshape(smax, kh, d)
            vv = v_pages[table[s]].reshape(smax, kh, d)
            for i in range(int(q_lens[s])):
                qpos = int(lengths[s]) - int(q_lens[s]) + i
                for hh in range(h):
                    khh = hh // (h // kh)
                    sc = (q[s, i, hh] * d ** -0.5) @ kv[:, khh].T
                    mask = np.arange(smax) <= qpos
                    if window:
                        mask &= np.arange(smax) > qpos - window
                    sc = np.where(mask, sc, -1e30)
                    p = np.exp(sc - sc.max())
                    p /= p.sum()
                    np.testing.assert_allclose(
                        out[s, i, hh], p @ vv[:, khh],
                        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mixed-step serving vs the gathered oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    return make_engine()


@pytest.fixture(scope="module")
def baseline(engine):
    """The gathered oracle: monolithic prefill, monolithic lanes."""
    reqs = mixed_requests(engine, MIXED[:4])
    return reqs, serve(engine, reqs)


class TestMixedStepTokenEquivalence:
    @pytest.mark.parametrize("chunk", [1, 3, 4, 7])
    def test_chunk_sizes_incl_page_and_beyond(self, engine, baseline,
                                              chunk):
        """chunk < page, == page (4), and > page, incl. single-token."""
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=4, prefill_chunk=chunk,
                     attn_backend="pallas_paged") == base

    @pytest.mark.parametrize("page", [1, 5])
    def test_page_sizes_one_and_odd(self, engine, baseline, page):
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=page, prefill_chunk=3,
                     attn_backend="pallas_paged") == base

    def test_wave_mode_and_budget(self, engine, baseline):
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=4, prefill_chunk=3,
                     mode="wave", attn_backend="pallas_paged") == base
        assert serve(engine, reqs, kv_page_size=4, prefill_chunk=2,
                     prefill_budget=16,
                     attn_backend="pallas_paged") == base

    @pytest.mark.parametrize("arch,chunk,page", [
        ("gemma2-2b", 1, 5), ("gemma2-2b", 7, 4),
        ("deepseek-v2-236b", 3, 1), ("deepseek-v2-236b", 5, 4)])
    def test_rolling_window_and_mla_archs(self, arch, chunk, page):
        """gemma2: rolling-window lanes run the ragged reference path
        beside paged global layers inside the same mixed trace; deepseek:
        MLA absorbed chunks through the kernel's second score operand."""
        engine = make_engine(arch)
        rng = np.random.default_rng(3)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, L), g)
                for L, g in [(20, 6), (4, 3), (11, 8)]]
        base = serve(engine, reqs)
        assert serve(engine, reqs, kv_page_size=page, prefill_chunk=chunk,
                     attn_backend="pallas_paged") == base


class TestMixedStepHotPath:
    def test_zero_gather_bytes_prefill_and_decode(self, engine, baseline):
        """The acceptance metric: under the mixed-step path neither the
        decode loop nor the prefill path copies any KV — no per-step page
        gather/scatter AND no install of a standalone prefill cache —
        while the gathered oracle moves both."""
        reqs, base = baseline
        engine.metrics = type(engine.metrics)()
        assert serve(engine, reqs, kv_page_size=4, prefill_chunk=3,
                     attn_backend="pallas_paged") == base
        m = engine.metrics
        assert m.kv_gather_bytes == 0
        assert m.kv_prefill_gather_bytes == 0
        assert m.kv_gather_bytes_avoided > 0
        assert m.kv_prefill_gather_bytes_avoided > 0
        assert "prefill gather" in m.stats_line()
        engine.metrics = type(engine.metrics)()
        serve(engine, reqs, kv_page_size=4, prefill_chunk=3)
        m = engine.metrics
        assert m.kv_gather_bytes > 0             # per-step page copies
        assert m.kv_prefill_gather_bytes > 0     # install copies
        assert m.kv_gather_bytes_avoided == 0
        assert m.kv_prefill_gather_bytes_avoided == 0

    def test_no_standalone_prefill_cache(self, engine, baseline):
        """Mixed-step admissions never allocate the batch-1 prefill cache
        — the slot's pcache stays None through its whole lifecycle."""
        reqs, _ = baseline
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=4, prefill_chunk=3,
                          attn_backend="pallas_paged")
        seen = []
        orig = sched._mixed_tick

        def checked(pool, completed):
            seen.extend(s.pcache for s in pool.prefilling())
            orig(pool, completed)

        sched._mixed_tick = checked
        for r in reqs:
            sched.submit(*r)
        done = sched.run()
        assert len(done) == len(reqs) and seen
        assert all(c is None for c in seen)

    def test_mixed_compiles_two_widths(self, engine, baseline):
        """Bounded compile count: chunked ticks trace at Q=prefill_chunk,
        pure-decode ticks at Q=1 — remainder chunks ride padded instead
        of compiling their own width."""
        reqs, base = baseline
        engine._mixed_jits.clear()
        assert serve(engine, reqs, kv_page_size=4, prefill_chunk=3,
                     attn_backend="pallas_paged") == base
        widths = sorted(k[2] for k in engine._mixed_jits)
        assert widths == [1, 3]

    def test_grow_pages_mid_serving_no_recompile(self, engine):
        """Growing the logical pool within page_capacity mid-serving must
        not touch the compiled mixed step and must keep tokens correct."""
        rng = np.random.default_rng(2)
        sched = Scheduler(engine, batch_size=2, buckets=(16,),
                          kv_page_size=4, kv_pages=5, kv_page_capacity=16,
                          prefill_chunk=3, attn_backend="pallas_paged")
        prompts = [rng.integers(0, engine.cfg.vocab_size, 8)
                   for _ in range(3)]
        sched.submit(prompts[0], 6)
        out1 = sched.run()
        assert len(out1) == 1
        keys = [k for k in engine._mixed_jits
                if k[:2] == (sched._pool.paged_flags, sched._pool.page_size)]
        c0 = {k: engine._mixed_jits[k]._cache_size() for k in keys}
        sched._pool.grow_pages(9)
        sched.submit(prompts[1], 6)
        sched.submit(prompts[2], 6)
        out2 = sched.run()
        assert len(out2) == 2
        assert {k: engine._mixed_jits[k]._cache_size()
                for k in keys} == c0
        assert sched._pool.allocator.n_allocated == 0
        ref = serve(engine, [(prompts[0], 6)], buckets=(16,))
        assert tuple(out1[0].generated) == ref[0]

    def test_no_pages_leaked_after_retire(self, engine, baseline):
        reqs, _ = baseline
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=4, prefill_chunk=3,
                          attn_backend="pallas_paged")
        for r in reqs:
            sched.submit(*r)
        sched.run()
        pool = sched._pool
        assert pool.allocator.n_allocated == 0
        assert pool.allocator.reserved == 0
        assert (pool.table == 0).all()
