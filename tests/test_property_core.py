"""Property tests for core/huffman.py + core/bitpack.py.

Two layers:

* deterministic seeds (always run): every check function below is
  exercised over a fixed seed grid, so the invariants are enforced even
  where hypothesis isn't installed (tests/_hypothesis_compat.py);
* hypothesis (CI): the same check functions driven by drawn seeds and
  shapes, exploring the input space much more widely.

Invariants:

* encode -> decode is the identity for arbitrary weight bitmaps, through
  both the code layer (encode_stream/decode_stream) and the packing layer
  (gemm/conv/word round-trips);
* compressed size respects the coder's bounds: never more than
  MAX_CODE_LEN bits per sequence, and for bitmaps whose distinct-sequence
  count fits the three table nodes (<= 160, guaranteed at the shapes drawn
  here) the stream never exceeds the 9-bit channel-packed baseline — the
  "compressed <= padded raw" guarantee the serving stack relies on.
"""

import numpy as np
import pytest

from repro.core import bitpack, compression, frequency, huffman
from repro.core.bitpack import NUM_SEQUENCES, SEQ_BITS
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

# shapes whose sequence count (n * ceil(k/9) <= 144) fits inside the three
# lookup nodes, so every occurring value gets a code of <= SEQ_BITS bits
MAX_N, MAX_K = 16, 81

SEED_GRID = [0, 1, 2, 3, 17, 255]


def random_bitmap(seed: int, n: int, k: int, skew: bool) -> np.ndarray:
    """(n, k) {0,1} bitmap; ``skew`` draws motif-structured rows (the
    paper's C1 shape), else i.i.d. uniform bits (adversarial entropy)."""
    rng = np.random.default_rng(seed)
    if not skew:
        return rng.integers(0, 2, (n, k)).astype(np.uint8)
    motifs = rng.integers(0, 2, (2, k)).astype(np.uint8)
    rows = motifs[rng.integers(0, 2, n)]
    flips = rng.random((n, k)) < 0.05
    return np.where(flips, 1 - rows, rows).astype(np.uint8)


# ---------------------------------------------------------------------------
# check functions (shared by deterministic grid + hypothesis drivers)
# ---------------------------------------------------------------------------

def check_stream_roundtrip_and_bounds(bits: np.ndarray) -> None:
    n, k = bits.shape
    seqs = bitpack.gemm_to_sequences(bits)
    hist = frequency.sequence_histogram(seqs)
    assign = huffman.assign_nodes(hist)
    words, nbits = huffman.encode_stream(seqs, assign)
    out = huffman.decode_stream(words, nbits, assign, count=seqs.size)
    np.testing.assert_array_equal(out, seqs.ravel())
    # bit-level identity back to the original bitmap
    np.testing.assert_array_equal(
        bitpack.sequences_to_gemm(out.reshape(seqs.shape), k), bits)
    # coder bounds: hard cap always; 9-bit baseline whenever every
    # occurring sequence fits the lookup nodes (always at these shapes)
    assert nbits <= seqs.size * huffman.MAX_CODE_LEN
    distinct = int(np.unique(seqs).size)
    if distinct <= sum(huffman.NODE_CAPS[:3]):
        assert nbits <= seqs.size * SEQ_BITS, \
            f"stream {nbits}b > padded raw {seqs.size * SEQ_BITS}b " \
            f"({distinct} distinct sequences)"
    # stored words cover exactly the stream (32-bit padding only)
    assert words.size == -(-nbits // 32)


def check_compress_gemm_roundtrip(bits: np.ndarray) -> None:
    ct = compression.compress_gemm(bits, cluster=False, tiled=False)
    np.testing.assert_array_equal(compression.decompress(ct), bits)
    assert ct.stream_bits <= ct.n_seqs * huffman.MAX_CODE_LEN


def check_conv_roundtrip(w_bits: np.ndarray) -> None:
    seqs = bitpack.kernel_to_sequences(w_bits)
    assert seqs.max(initial=0) < NUM_SEQUENCES
    np.testing.assert_array_equal(bitpack.sequences_to_kernel(seqs), w_bits)


def check_word_packing_roundtrip(bits_flat: np.ndarray) -> None:
    words = bitpack.pack_bits(bits_flat)
    assert words.dtype == np.uint32
    np.testing.assert_array_equal(bitpack.unpack_bits(words), bits_flat)


def check_gemm_operand_roundtrip(bits: np.ndarray) -> None:
    words = bitpack.pack_gemm_operand(bits)
    np.testing.assert_array_equal(
        bitpack.unpack_gemm_operand(words, bits.shape[1]), bits)


# ---------------------------------------------------------------------------
# deterministic grid (runs with or without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEED_GRID)
@pytest.mark.parametrize("skew", [False, True])
def test_stream_roundtrip_grid(seed, skew):
    rng = np.random.default_rng(seed + 1000)
    n, k = int(rng.integers(1, MAX_N + 1)), int(rng.integers(1, MAX_K + 1))
    check_stream_roundtrip_and_bounds(random_bitmap(seed, n, k, skew))


@pytest.mark.parametrize("seed", SEED_GRID)
def test_compress_gemm_roundtrip_grid(seed):
    rng = np.random.default_rng(seed + 2000)
    n, k = int(rng.integers(1, MAX_N + 1)), int(rng.integers(1, MAX_K + 1))
    check_compress_gemm_roundtrip(random_bitmap(seed, n, k, True))


@pytest.mark.parametrize("seed", SEED_GRID)
def test_conv_and_packing_grid(seed):
    rng = np.random.default_rng(seed + 3000)
    cout, cin = int(rng.integers(1, 9)), int(rng.integers(1, 9))
    check_conv_roundtrip(
        rng.integers(0, 2, (cout, cin, 3, 3)).astype(np.uint8))
    m = int(rng.integers(1, 5))
    check_word_packing_roundtrip(
        rng.integers(0, 2, (3, m * 32)).astype(np.uint8))
    n, k = int(rng.integers(1, 7)), int(rng.integers(1, 400))
    check_gemm_operand_roundtrip(rng.integers(0, 2, (n, k)).astype(np.uint8))


def test_all_escape_bitmap_still_roundtrips():
    """>160 distinct sequences forces escape codes; identity must hold and
    the 12-bit hard cap is the only size guarantee left."""
    seqs = np.arange(NUM_SEQUENCES, dtype=np.uint16).reshape(32, 16)
    bits = bitpack.sequences_to_gemm(seqs, 16 * SEQ_BITS)
    n, k = bits.shape
    out_seqs = bitpack.gemm_to_sequences(bits)
    np.testing.assert_array_equal(out_seqs, seqs)
    hist = frequency.sequence_histogram(seqs)
    assign = huffman.assign_nodes(hist)
    words, nbits = huffman.encode_stream(seqs, assign)
    out = huffman.decode_stream(words, nbits, assign, count=seqs.size)
    np.testing.assert_array_equal(out, seqs.ravel())
    assert nbits <= seqs.size * huffman.MAX_CODE_LEN


# ---------------------------------------------------------------------------
# hypothesis drivers (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    seed_st = st.integers(min_value=0, max_value=2 ** 32 - 1)

    @settings(max_examples=60, deadline=None)
    @given(seed=seed_st, n=st.integers(1, MAX_N), k=st.integers(1, MAX_K),
           skew=st.booleans())
    def test_stream_roundtrip_property(seed, n, k, skew):
        check_stream_roundtrip_and_bounds(random_bitmap(seed, n, k, skew))

    @settings(max_examples=25, deadline=None)
    @given(seed=seed_st, n=st.integers(1, MAX_N), k=st.integers(1, MAX_K))
    def test_compress_gemm_roundtrip_property(seed, n, k):
        check_compress_gemm_roundtrip(random_bitmap(seed, n, k, True))

    @settings(max_examples=40, deadline=None)
    @given(seed=seed_st, cout=st.integers(1, 12), cin=st.integers(1, 12))
    def test_conv_roundtrip_property(seed, cout, cin):
        rng = np.random.default_rng(seed)
        check_conv_roundtrip(
            rng.integers(0, 2, (cout, cin, 3, 3)).astype(np.uint8))

    @settings(max_examples=40, deadline=None)
    @given(seed=seed_st, rows=st.integers(1, 5), m=st.integers(1, 6))
    def test_word_packing_property(seed, rows, m):
        rng = np.random.default_rng(seed)
        check_word_packing_roundtrip(
            rng.integers(0, 2, (rows, m * 32)).astype(np.uint8))

    @settings(max_examples=40, deadline=None)
    @given(seed=seed_st, n=st.integers(1, 8), k=st.integers(1, 600))
    def test_gemm_operand_property(seed, n, k):
        rng = np.random.default_rng(seed)
        check_gemm_operand_roundtrip(
            rng.integers(0, 2, (n, k)).astype(np.uint8))
else:                                                 # pragma: no cover
    @given()
    def test_stream_roundtrip_property():
        """Placeholder: skips with a clear reason when hypothesis is
        missing (the deterministic grid above still runs)."""
