"""Observability layer: histogram math vs numpy oracles, the
Prometheus registry round-trip, Chrome-trace well-formedness, serving
span trees (every admitted request retires exactly once, spans nest,
timestamps monotone), windowed stats-line semantics, token-identity
with telemetry on vs off, and the capacity-autotune knee.

Serving tests run the gathered backend (the pure-jnp oracle), so the
whole file is tier-1 — no pallas marker.
"""

import json
import math

import jax
import numpy as np
import pytest

from repro.models.api import get_model
from repro.runtime import (NULL_TELEMETRY, DecodeTileCache, Histogram,
                           MetricsRegistry, Scheduler, ServeEngine,
                           ServeMetrics, Telemetry, Tracer, WeightStore,
                           find_knee, parse_prom, recommend_store_capacity,
                           sweep_store)
from repro.runtime.telemetry import (NULL_TRACER, PID_ENGINE, PID_REQUEST,
                                     NullTelemetry)
from tests.test_models import reduced

# ---------------------------------------------------------------------------
# histogram math vs numpy oracles
# ---------------------------------------------------------------------------

BUCKET_RATIO = 10 ** (1 / 5)      # default per_decade=5 -> one-bucket error


class TestHistogram:
    def test_counts_sum_and_moments(self):
        h = Histogram()
        vals = [1e-4, 3e-3, 3e-3, 0.5, 2.0]
        for v in vals:
            h.record(v)
        assert h.n == len(vals) == sum(h.counts)
        assert h.total == pytest.approx(sum(vals))
        assert h.mean() == pytest.approx(np.mean(vals))
        assert h.min == min(vals) and h.max == max(vals)

    def test_empty(self):
        h = Histogram()
        assert h.n == 0
        assert h.mean() == 0.0
        assert h.percentile(50) == 0.0

    def test_single_value_clamps_to_it(self):
        h = Histogram()
        h.record(0.0371)
        for p in (1, 50, 99, 100):
            assert h.percentile(p) == 0.0371

    def test_overflow_bucket_reports_max(self):
        h = Histogram(lo=1e-6, hi=120.0)
        h.record(500.0)           # above the largest edge
        h.record(900.0)
        assert h.counts[-1] == 2
        assert h.percentile(99) == 900.0

    def test_underflow_lands_in_bucket_zero(self):
        h = Histogram(lo=1e-6)
        h.record(1e-9)
        assert h.counts[0] == 1
        assert h.percentile(50) == pytest.approx(1e-9)   # clamped to min

    @pytest.mark.parametrize("p", [50, 90, 99])
    def test_percentile_vs_numpy_exact_rank(self, p):
        """The estimate must land within one bucket ratio of the exact
        rank-based percentile — the constant relative error the
        geometric bucket edges guarantee."""
        rng = np.random.default_rng(0)
        vals = np.exp(rng.normal(-4.0, 1.2, size=5000))   # ~ms-scale
        h = Histogram()
        for v in vals:
            h.record(float(v))
        exact = float(np.sort(vals)[max(1, math.ceil(p / 100 * len(vals)))
                                    - 1])
        est = h.percentile(p)
        assert exact / BUCKET_RATIO <= est <= exact * BUCKET_RATIO

    def test_estimate_always_inside_value_range(self):
        rng = np.random.default_rng(1)
        h = Histogram()
        vals = rng.uniform(1e-5, 10.0, 200)
        for v in vals:
            h.record(float(v))
        for p in (0.1, 25, 50, 75, 99.9):
            assert vals.min() <= h.percentile(p) <= vals.max()


# ---------------------------------------------------------------------------
# metrics registry -> Prometheus text -> parse_prom round-trip
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_round_trip(self):
        reg = MetricsRegistry()
        state = {"c": 7, "g": 0.25}
        reg.counter("things_total", lambda: state["c"], "things done")
        reg.gauge("fullness", lambda: state["g"])
        out = parse_prom(reg.render())
        assert out[("repro_things_total", "")] == 7
        assert out[("repro_fullness", "")] == 0.25
        state["c"] = 9                      # pull-based: re-render sees it
        assert parse_prom(reg.render())[("repro_things_total", "")] == 9

    def test_histogram_render_cumulative(self):
        reg = MetricsRegistry()
        h = Histogram()
        for v in (1e-4, 1e-4, 0.01, 5.0):
            h.record(v)
        reg.histogram("lat_seconds", h, "latency")
        out = parse_prom(reg.render())
        buckets = [(k, v) for k, v in out.items()
                   if k[0] == "repro_lat_seconds_bucket"]
        # cumulative and capped by the +Inf bucket == count
        vals = [v for _, v in buckets]
        assert vals == sorted(vals)
        assert out[("repro_lat_seconds_bucket", 'le="+Inf"')] == 4
        assert out[("repro_lat_seconds_count", "")] == 4
        assert out[("repro_lat_seconds_sum", "")] == pytest.approx(
            h.total)

    def test_rejects_bad_and_duplicate_names(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.counter("bad name", lambda: 0)
        reg.counter("ok_total", lambda: 0)
        with pytest.raises(ValueError):
            reg.counter("ok_total", lambda: 0)

    def test_parse_prom_rejects_malformed(self):
        with pytest.raises(ValueError):
            parse_prom("this is not prometheus\n")
        with pytest.raises(ValueError):
            parse_prom("metric_name not_a_number\n")
        assert parse_prom("# just a comment\n\n") == {}

    def test_sample_scalars_only(self):
        reg = MetricsRegistry()
        reg.counter("a_total", lambda: 3)
        reg.histogram("h_seconds", Histogram())
        assert reg.sample() == {"repro_a_total": 3.0}


# ---------------------------------------------------------------------------
# tracer: chrome JSON round-trip
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_and_instant_round_trip(self, tmp_path):
        tr = Tracer()
        tr.name_track(PID_REQUEST, 3, "request 3")
        with tr.span(PID_ENGINE, 0, "phase", k=1):
            tr.instant(PID_REQUEST, 3, "mark")
        obj = json.loads(json.dumps(tr.chrome()))     # JSON round-trip
        evs = obj["traceEvents"]
        spans = [e for e in evs if e["ph"] == "X"]
        inst = [e for e in evs if e["ph"] == "i"]
        meta = [e for e in evs if e["ph"] == "M"]
        assert len(spans) == 1 and spans[0]["name"] == "phase"
        assert spans[0]["dur"] >= 0 and spans[0]["ts"] >= 0
        assert spans[0]["args"] == {"k": 1}
        assert len(inst) == 1 and inst[0]["s"] == "t"
        # metadata names both the processes and the request track
        assert {(m["name"], m["pid"]) for m in meta} >= {
            ("process_name", PID_REQUEST), ("process_name", PID_ENGINE),
            ("thread_name", PID_REQUEST)}
        # file export self-loads
        p = tmp_path / "trace.json"
        tr.write_chrome(p)
        assert json.loads(p.read_text())["traceEvents"]
        pl = tmp_path / "trace.jsonl"
        tr.write_jsonl(pl)
        assert all(json.loads(line)
                   for line in pl.read_text().splitlines())

    def test_instant_inside_span_window(self):
        tr = Tracer()
        with tr.span(PID_ENGINE, 0, "outer"):
            tr.instant(PID_ENGINE, 0, "inside")
        span = next(e for e in tr.events if e["ph"] == "X")
        mark = next(e for e in tr.events if e["ph"] == "i")
        assert span["ts"] <= mark["ts"] <= span["ts"] + span["dur"]


class TestNullPaths:
    def test_null_telemetry_is_free_and_silent(self):
        tel = NULL_TELEMETRY
        assert isinstance(tel, NullTelemetry)
        assert tel.tracing is False and tel.tracer is NULL_TRACER
        ctx = tel.timed("anything", slot=1)
        assert tel.timed("other") is ctx       # one shared null context
        with ctx:
            pass
        assert tel.phases == {}

    def test_untraced_telemetry_keeps_histograms_only(self):
        tel = Telemetry(trace=False)
        with tel.timed("work"):
            pass
        assert tel.tracing is False
        assert tel.phases["work"].n == 1

    def test_traced_telemetry_emits_engine_span(self):
        tel = Telemetry(trace=True)
        with tel.timed("work", detail=2):
            pass
        (ev,) = tel.tracer.events
        assert ev["name"] == "work" and ev["pid"] == PID_ENGINE
        assert ev["args"] == {"detail": 2}
        assert tel.phases["work"].n == 1


# ---------------------------------------------------------------------------
# windowed stats-line semantics
# ---------------------------------------------------------------------------

class TestWindows:
    def test_first_window_is_lifetime_then_deltas(self):
        m = ServeMetrics()
        m.record_decode_step(4, 0.5, n_slots=4)
        w1 = m.window()
        assert w1["slot_steps"] == 4 and w1["decode_s"] == 0.5
        m.record_decode_step(2, 0.25, n_slots=4)
        w2 = m.window()
        assert w2["slot_steps"] == 2 and w2["decode_s"] == 0.25
        assert m.slot_steps == 6               # lifetime counters intact
        assert m.window()["slot_steps"] == 0   # empty window

    def test_stats_line_reports_window_rate(self):
        m = ServeMetrics()
        m.record_decode_step(10, 1.0, n_slots=10)
        m.window()                              # close the first window
        m.record_decode_step(1, 1.0, n_slots=10)
        line = m.stats_line()
        assert "1.0 tok/s" in line              # window rate, not (11/2)
        assert "tokens 11" in line              # lifetime total stays

    def test_stats_line_has_latency_percentiles(self):
        m = ServeMetrics()
        m.record_ttft(0.01)
        m.tpot_hist.record(0.002)
        line = m.stats_line()
        assert "ttft p50" in line and "tpot p50" in line

    def test_cache_hit_rate_windowed(self):
        m = ServeMetrics()
        cache = DecodeTileCache()
        cache.get_or_decode(("k",), lambda: 1, nbytes=8)    # miss
        m.window(cache)
        cache.get_or_decode(("k",), lambda: 1, nbytes=8)    # hit
        assert "hit-rate 100.0%" in m.stats_line(cache)


# ---------------------------------------------------------------------------
# serving span trees + prometheus (gathered backend -> tier-1)
# ---------------------------------------------------------------------------

REQS = [(5, 4), (11, 2), (3, 5)]


def make_engine(telemetry=None):
    cfg = reduced("minitron-8b")
    params = jax.tree_util.tree_map(
        np.asarray, get_model(cfg).init_params(cfg, jax.random.PRNGKey(0)))
    return ServeEngine(cfg, params, compress=True, telemetry=telemetry)


def serve(engine, reqs, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("buckets", (16,))
    sched = Scheduler(engine, **kw)
    rids = [sched.submit(np.asarray(p), g).rid for p, g in reqs]
    done = {r.rid: r for r in sched.run()}
    assert len(done) == len(reqs)
    return rids, [tuple(done[rid].generated) for rid in rids]


@pytest.fixture(scope="module")
def reqs():
    rng = np.random.default_rng(5)
    return [(rng.integers(0, 128, L), g) for L, g in REQS]


@pytest.fixture(scope="module")
def baseline(reqs):
    _, toks = serve(make_engine(), reqs,
                    prefill_chunk=4, kv_page_size=8)
    return toks


@pytest.fixture(scope="module")
def traced(reqs):
    tel = Telemetry(trace=True)
    engine = make_engine(telemetry=tel)
    rids, toks = serve(engine, reqs, prefill_chunk=4, kv_page_size=8)
    return engine, tel, rids, toks


class TestServingSpans:
    def test_tokens_identical_with_telemetry(self, baseline, traced):
        """The acceptance invariant: telemetry observes, never steers."""
        assert traced[3] == baseline

    def test_every_request_retires_exactly_once(self, traced, reqs):
        _, tel, rids, _ = traced
        evs = tel.tracer.chrome()["traceEvents"]
        req_evs = [e for e in evs
                   if e.get("pid") == PID_REQUEST and e["ph"] != "M"]
        by_name: dict = {}
        for e in req_evs:
            by_name.setdefault(e["name"], []).append(e)
        n = len(reqs)
        assert len(by_name["queued"]) == n
        assert len(by_name["request"]) == n
        assert len(by_name["admitted"]) == n
        assert len(by_name["retired"]) == n
        # one lifecycle per rid, on that rid's own track
        for name in ("queued", "request", "admitted", "retired"):
            assert sorted(e["tid"] for e in by_name[name]) == sorted(rids)

    def test_spans_nest_and_timestamps_monotone(self, traced):
        _, tel, rids, _ = traced
        evs = tel.tracer.chrome()["traceEvents"]
        eps = 1.0                                         # 1 us slack
        for rid in rids:
            track = [e for e in evs
                     if e.get("pid") == PID_REQUEST and e.get("tid") == rid
                     and e["ph"] != "M"]
            get = {e["name"]: e for e in track if e["ph"] == "X"}
            req, queued = get["request"], get["queued"]
            assert req["ts"] >= 0 and req["dur"] >= 0
            # queued starts the request span and ends inside it
            assert abs(queued["ts"] - req["ts"]) <= eps
            end = req["ts"] + req["dur"] + eps
            assert queued["ts"] + queued["dur"] <= end
            # every span/instant on the track lies inside [start, end]
            for e in track:
                assert req["ts"] - eps <= e["ts"] <= end
                if e["ph"] == "X":
                    assert e["ts"] + e["dur"] <= end
            # decode follows admission: first_token after queued ends
            if "decode" in get:
                assert get["decode"]["ts"] >= queued["ts"] + queued["dur"] \
                    - eps

    def test_chunk_spans_cover_each_prompt(self, traced, reqs):
        _, tel, rids, _ = traced
        evs = tel.tracer.events
        for rid, (prompt, _) in zip(rids, reqs):
            chunks = [e for e in evs
                      if e.get("tid") == rid and e["ph"] == "X"
                      and e["name"] == "prefill_chunk"]
            assert sum(e["args"]["tokens"] for e in chunks) == len(prompt)
            cursors = [e["args"]["cursor"] for e in chunks]
            assert cursors == sorted(cursors)     # chunks advance in order

    def test_engine_phase_spans_present(self, traced):
        _, tel, _, _ = traced
        names = {e["name"] for e in tel.tracer.events
                 if e["pid"] == PID_ENGINE and e["ph"] == "X"}
        assert {"decode", "prefill"} <= names
        assert {"admit", "decode", "prefill"} <= set(tel.phases)

    def test_latency_histograms_filled(self, traced, reqs):
        engine, _, _, _ = traced
        m = engine.metrics
        assert m.ttft_hist.n == len(reqs)
        assert m.e2e_hist.n == len(reqs)
        assert m.tpot_hist.n == sum(1 for _, g in REQS if g > 1)
        assert m.chunk_hist.n == m.prefill_chunks
        assert m.step_hist.n == m.decode_steps

    def test_prometheus_parses_and_counters_monotone(self, traced, reqs):
        engine, _, _, _ = traced
        first = parse_prom(engine.render_prom())
        serve(engine, reqs, prefill_chunk=4, kv_page_size=8)
        second = parse_prom(engine.render_prom())
        monotone = [k for k in first
                    if k[0].endswith(("_total", "_count", "_bucket"))
                    or k[1].startswith("le=")]
        assert monotone
        for k in monotone:
            assert second[k] >= first[k], k
        # the scrape covers serving + cache + store + phase families
        fams = {k[0] for k in second}
        assert "repro_tokens_generated_total" in fams
        assert "repro_cache_hits_total" in fams
        assert "repro_store_prefetch_dispatched_total" in fams
        assert any(f.startswith("repro_phase_") for f in fams)


# ---------------------------------------------------------------------------
# capacity autotune
# ---------------------------------------------------------------------------

class TestAutotune:
    def test_find_knee_picks_cliff_not_max_capacity(self):
        caps = [10, 20, 30, 40, 50]
        rates = [0.05, 0.10, 0.80, 0.81, 0.82]
        assert find_knee(caps, rates) == 2     # knee at the cliff

    def test_find_knee_respects_tolerance(self):
        caps = [10, 20, 30]
        rates = [0.10, 0.70, 0.80]             # cliff at 1, but 0.70 is
        assert find_knee(caps, rates, tolerance=0.02) == 2   # too far off
        assert find_knee(caps, rates, tolerance=0.15) == 1

    def test_find_knee_guarantee(self):
        rng = np.random.default_rng(2)
        for _ in range(20):
            rates = list(rng.uniform(0, 1, 6))
            i = find_knee(list(range(6)), rates, tolerance=0.02)
            assert rates[i] >= max(rates) - 0.02

    def test_find_knee_staircase_prefers_latest_jump(self):
        """Equal-size jumps tie-break toward the *latest* riser: on a
        staircase curve Python's ``max()`` alone would return the first
        maximal jump — a capacity still inside the thrashing region."""
        caps = [10, 20, 30, 40]
        rates = [0.10, 0.40, 0.70, 1.00]       # three equal 0.30 jumps
        assert find_knee(caps, rates) == 3
        # a genuinely larger early jump still wins over later small ones
        assert find_knee([10, 20, 30], [0.0, 0.8, 0.81]) == 1

    def test_sweep_store_clamps_tiny_models(self):
        """A model whose working set rounds ``int(ws * frac)`` below one
        decoded tile must still sweep non-degenerate caches: every
        capacity is clamped up to the largest decoded tile, so the
        full-capacity point hits (steps-1)/steps instead of 0."""
        w = np.ones((4, 16), np.float32)       # tiny: one tile per layer
        store = WeightStore(DecodeTileCache())
        store.register_model("tiny", {"up": w}, select=lambda p, nd: True)
        caps, rates = sweep_store(store, "tiny", steps=8)
        tile = max(ts.c * ts.s * 4
                   for _, stack in store.layers("tiny").items()
                   for ts in [stack[0].ensure_tiled()])
        assert all(c >= tile for c in caps)
        assert rates[-1] == pytest.approx(7 / 8)
        rec = recommend_store_capacity(store, "tiny", steps=8)
        assert rec["capacity"] >= tile and rec["hit_rate"] > 0

    def test_find_knee_rejects_bad_input(self):
        with pytest.raises(ValueError):
            find_knee([1, 2], [0.5])
        with pytest.raises(ValueError):
            find_knee([], [])

    def test_recommend_store_capacity(self):
        rng = np.random.default_rng(0)
        w = rng.standard_normal((64, 256)).astype(np.float32)
        store = WeightStore(DecodeTileCache())
        store.register_model("m", {"up": w}, select=lambda p, nd: True)
        rec = recommend_store_capacity(store, "m", steps=8)
        ws = store.decoded_bytes("m")
        assert rec["working_set"] == ws
        assert 0 < rec["capacity"] <= ws
        assert rec["capacity"] == ws * rec["fraction"] // 1 or \
            rec["capacity"] == int(ws * rec["fraction"])
        assert 0.0 <= rec["hit_rate"] <= rec["best_rate"] <= 1.0
        assert len(rec["capacities"]) == len(rec["rates"])
        # the cyclic scan at full capacity hits (steps-1)/steps
        assert rec["rates"][-1] == pytest.approx(7 / 8)
