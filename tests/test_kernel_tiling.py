"""Hardware-shaped kernel launch: tiled pools, multi-page grid steps,
gathered dequant, and the launch-shape autotuner.

The tiling-equivalence contract: a pool padded toward the TPU's
(8, 128) sublane/lane register tiles, walked ``pages_per_step`` pages
per grid step, must stay *token-identical* to the identity layout —
padding is masked inside the online softmax, zero feature columns drop
out of every dot product, and regrouped page DMAs only reassociate the
online-softmax accumulation (the same tolerance regime as the
kernel-vs-dense-oracle tests).  At ``pages_per_step=1`` the padded
kernel output is **bit-identical** to the unpadded one; the serve-level
suites assert token identity across the full launch-shape grid.

Also here: the gathered codebook dequant vs the one-hot reference
(bit-identity regression for the satellite that replaced the
O(page*256) one-hot matmul), the ``kernel_qblock_rounded`` telemetry
for gcd-rounded q_blocks, and ``tune_kernel`` unit tests.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.runtime.scheduler as sched_mod
from repro.kernels import kv_codec
from repro.kernels.paged_attention import (effective_q_block,
                                           paged_mixed_attention)
from repro.models.api import (TILE_LANE, TILE_SUBLANE, padded_page_dims,
                              round_up)
from repro.runtime import Scheduler, tune_kernel
from repro.runtime.autotune import _KERNEL_TUNE_CACHE
from tests.harness import (MIXED, assert_tokens_identical, make_engine,
                           mixed_requests, run_trace)
from tests.test_paged_attention import random_paged_cache

pytestmark = pytest.mark.pallas


def pad_pool(pool, rows, feat_last, fill=0):
    """Zero-pad a (n_pages, page, KH, D) pool to (n_pages, rows, KH,
    feat_last) — the SlotPool hardware-tiled layout."""
    p = np.full((pool.shape[0], rows, *pool.shape[2:-1], feat_last),
                fill, pool.dtype)
    p[:, :pool.shape[1], ..., :pool.shape[-1]] = pool
    return p


class TestPaddedPageDims:
    def test_identity_when_off(self):
        assert padded_page_dims((1, 4, 2, 16), 1, 4, False) == (4, (2, 16))

    def test_pads_sublane_and_lane(self):
        rows, feat = padded_page_dims((1, 4, 2, 16), 1, 4, True)
        assert rows == TILE_SUBLANE and feat == (2, TILE_LANE)

    def test_aligned_dims_untouched(self):
        rows, feat = padded_page_dims((1, 16, 2, 256), 1, 16, True)
        assert rows == 16 and feat == (2, 256)

    def test_featureless_leaf(self):
        assert padded_page_dims((1, 3), 1, 3, True) == (TILE_SUBLANE, ())


class TestTilingEquivalenceKernel:
    """Padded pools vs the identity layout at the kernel level."""

    @pytest.mark.parametrize("page,pages", [(1, 8), (4, 5), (5, 3)])
    @pytest.mark.parametrize("pps", [1, 2, 4])
    def test_padded_matches_unpadded(self, page, pages, pps):
        rng = np.random.default_rng(page * 10 + pps)
        s, kh, d, dv = 3, 2, 16, 16
        q_lens = np.array([2, 4, 1], np.int32)
        k, v, table, lengths = random_paged_cache(rng, s, kh, d, dv, page,
                                                  pages)
        # the kernel contract: q_lens[s] new tokens are part of
        # lengths[s]; rows past it are finite garbage the caller ignores
        # (and garbage legitimately depends on the page grouping)
        lengths = np.maximum(lengths, q_lens)
        q = rng.normal(size=(s, 4, 4, d)).astype(np.float32)
        base = np.asarray(paged_mixed_attention(
            q, k, v, table, lengths, q_lens, interpret=True))
        rows, feat = round_up(page, TILE_SUBLANE), round_up(d, TILE_LANE)
        out = np.asarray(paged_mixed_attention(
            q, pad_pool(k, rows, feat), pad_pool(v, rows, feat),
            table, lengths, q_lens, page_size=page, pages_per_step=pps,
            interpret=True))[..., :dv]
        for i in range(s):
            got, want = out[i, :q_lens[i]], base[i, :q_lens[i]]
            if pps == 1:
                # row/lane padding alone is bit-exact: padded rows score
                # NEG_INF (exp underflows to 0.0) and zero columns add
                # nothing to any f32 dot
                np.testing.assert_array_equal(got, want)
            else:
                # multi-page steps regroup the online softmax — same
                # tolerance regime as the kernel-vs-dense oracle
                np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-6)

    @pytest.mark.parametrize("pps", [2, 4])
    def test_non_divisor_page_count(self, pps):
        """Logical page counts the group width does not divide pad the
        table with dummy-page entries — all masked, tokens unchanged."""
        rng = np.random.default_rng(3)
        s, kh, d, dv, page, pages = 2, 2, 8, 8, 4, 3   # 3 % pps != 0
        q_lens = np.array([3, 1], np.int32)
        k, v, table, lengths = random_paged_cache(rng, s, kh, d, dv, page,
                                                  pages)
        lengths = np.maximum(lengths, q_lens)
        q = rng.normal(size=(s, 3, 4, d)).astype(np.float32)
        base = np.asarray(paged_mixed_attention(
            q, k, v, table, lengths, q_lens, interpret=True))
        out = np.asarray(paged_mixed_attention(
            q, k, v, table, lengths, q_lens, pages_per_step=pps,
            interpret=True))
        for i in range(s):
            np.testing.assert_allclose(out[i, :q_lens[i]],
                                       base[i, :q_lens[i]],
                                       rtol=2e-6, atol=2e-6)

    def test_poisoned_dummy_sink_under_padding(self):
        """Garbage in page 0 — including its padded rows — must never
        reach any output: every reference to it is masked."""
        rng = np.random.default_rng(4)
        s, kh, d, dv, page, pages = 2, 2, 8, 8, 4, 4
        q_lens = np.array([2, 3], np.int32)
        k, v, table, lengths = random_paged_cache(rng, s, kh, d, dv, page,
                                                  pages)
        lengths = np.maximum(lengths, q_lens)
        rows, feat = TILE_SUBLANE, round_up(d, TILE_LANE)
        kp, vp = pad_pool(k, rows, feat), pad_pool(v, rows, feat)
        q = rng.normal(size=(s, 3, 4, d)).astype(np.float32)
        clean = np.asarray(paged_mixed_attention(
            q, kp, vp, table, lengths, q_lens, page_size=page,
            pages_per_step=2, interpret=True))
        kp2, vp2 = kp.copy(), vp.copy()
        kp2[0], vp2[0] = 1e9, 1e9
        poisoned = np.asarray(paged_mixed_attention(
            q, kp2, vp2, table, lengths, q_lens, page_size=page,
            pages_per_step=2, interpret=True))
        for i in range(s):
            np.testing.assert_array_equal(poisoned[i, :q_lens[i]],
                                          clean[i, :q_lens[i]])


class TestDequantGather:
    """The gathered codebook lookup vs the one-hot reference path."""

    def test_gather_bitwise_matches_onehot(self):
        rng = np.random.default_rng(5)
        s, kh, d, dv, page, pages = 3, 2, 16, 16, 4, 4
        q_lens = np.array([2, 4, 1], np.int32)
        k, v, table, lengths = random_paged_cache(rng, s, kh, d, dv, page,
                                                  pages)
        lengths = np.maximum(lengths, q_lens)
        ck, ks = kv_codec.encode(jnp.asarray(k), axes=(-2, -1))
        cv, vs = kv_codec.encode(jnp.asarray(v), axes=(-2, -1))
        q = rng.normal(size=(s, 4, 4, d)).astype(np.float32)
        kw = dict(k_scales=ks, v_scales=vs, codebook=kv_codec.codebook(),
                  interpret=True)
        a = np.asarray(paged_mixed_attention(
            q, ck, cv, table, lengths, q_lens, dequant="gather", **kw))
        b = np.asarray(paged_mixed_attention(
            q, ck, cv, table, lengths, q_lens, dequant="onehot", **kw))
        np.testing.assert_array_equal(a, b)

    def test_codec_padded_pool_matches_unpadded(self):
        """int8 code pools padded with zero codes decode the padding to
        exactly 0.0 (zero-centred codebook), so the padded codec kernel
        is bit-identical at pps=1."""
        rng = np.random.default_rng(6)
        s, kh, d, dv, page, pages = 2, 2, 16, 16, 4, 4
        q_lens = np.array([3, 2], np.int32)
        k, v, table, lengths = random_paged_cache(rng, s, kh, d, dv, page,
                                                  pages)
        lengths = np.maximum(lengths, q_lens)
        ck, ks = kv_codec.encode(jnp.asarray(k), axes=(-2, -1))
        cv, vs = kv_codec.encode(jnp.asarray(v), axes=(-2, -1))
        q = rng.normal(size=(s, 3, 4, d)).astype(np.float32)
        cb = kv_codec.codebook()
        base = np.asarray(paged_mixed_attention(
            q, ck, cv, table, lengths, q_lens, k_scales=ks, v_scales=vs,
            codebook=cb, interpret=True))
        rows, feat = TILE_SUBLANE, round_up(d, TILE_LANE)
        pad_s = np.zeros((ks.shape[0], rows), np.float32)
        pad_s[:, :page] = np.asarray(ks)
        pad_vs = np.zeros((vs.shape[0], rows), np.float32)
        pad_vs[:, :page] = np.asarray(vs)
        out = np.asarray(paged_mixed_attention(
            q, pad_pool(np.asarray(ck), rows, feat),
            pad_pool(np.asarray(cv), rows, feat),
            table, lengths, q_lens, k_scales=pad_s, v_scales=pad_vs,
            codebook=cb, page_size=page, interpret=True))[..., :dv]
        for i in range(s):
            np.testing.assert_array_equal(out[i, :q_lens[i]],
                                          base[i, :q_lens[i]])


@pytest.fixture(scope="module")
def engine():
    return make_engine("minitron-8b")


@pytest.fixture(scope="module")
def baseline(engine):
    reqs = mixed_requests(engine, MIXED[:4])
    return reqs, run_trace(engine, reqs, prefill_chunk=4,
                           attn_backend="gathered", kv_page_size=4)


class TestTilingEquivalenceServe:
    """Padded + multi-page serving vs the gathered oracle, token level."""

    @pytest.mark.parametrize("page", [1, 4, 5])
    @pytest.mark.parametrize("pps", [1, 2, 4])
    def test_tokens_identical_across_launch_shapes(self, engine, baseline,
                                                   page, pps):
        reqs, want = baseline
        got = run_trace(engine, reqs, prefill_chunk=4,
                        attn_backend="pallas_paged", kv_page_size=page,
                        kernel_tune=f"0,{pps}")
        assert_tokens_identical(got, want,
                                f"tiled page={page} pps={pps}")

    @pytest.mark.parametrize("arch,page,pps", [
        ("gemma2-2b", 4, 2),          # windowed + softcap layers
        ("deepseek-v2-236b", 3, 4),   # MLA absorbed two-operand path
    ])
    def test_other_archs(self, arch, page, pps):
        eng = make_engine(arch)
        reqs = mixed_requests(eng, MIXED[:3])
        want = run_trace(eng, reqs, prefill_chunk=4,
                         attn_backend="gathered", kv_page_size=page)
        got = run_trace(eng, reqs, prefill_chunk=4,
                        attn_backend="pallas_paged", kv_page_size=page,
                        kernel_tune=f"0,{pps}")
        assert_tokens_identical(got, want, f"tiled {arch}")

    def test_codec_tokens_identical(self, engine):
        reqs = mixed_requests(engine, MIXED[:3])
        want = run_trace(engine, reqs, prefill_chunk=4,
                         attn_backend="pallas_paged", kv_page_size=4,
                         kv_codec="cluster")
        got = run_trace(engine, reqs, prefill_chunk=4,
                        attn_backend="pallas_paged", kv_page_size=4,
                        kv_codec="cluster", kernel_tune="0,2")
        assert_tokens_identical(got, want, "tiled codec")

    def test_explicit_qblock(self, engine, baseline):
        reqs, want = baseline
        got = run_trace(engine, reqs, prefill_chunk=4,
                        attn_backend="pallas_paged", kv_page_size=4,
                        kernel_tune="2,2")
        assert_tokens_identical(got, want, "tiled qb=2")


class TestQblockRounding:
    def test_effective_q_block(self):
        assert effective_q_block(8, 0) == 8
        assert effective_q_block(8, 4) == 4
        assert effective_q_block(6, 4) == 2
        assert effective_q_block(5, 4) == 1

    def test_rounding_counted_and_warned(self, engine):
        """A tuned q_block that does not divide the mixed step's Q must
        bump kernel_qblock_rounded and warn once."""
        engine.metrics.kernel_qblock_rounded = 0
        sched_mod._QBLOCK_WARNED.clear()
        reqs = mixed_requests(engine, MIXED[:2])
        with pytest.warns(RuntimeWarning, match="does not divide"):
            # chunk width 3 with q_block 2: gcd(3, 2) = 1 rounds every
            # chunked step
            run_trace(engine, reqs, prefill_chunk=3,
                      attn_backend="pallas_paged", kv_page_size=4,
                      kernel_tune="2,1")
        assert engine.metrics.kernel_qblock_rounded > 0

    def test_dividing_qblock_not_counted(self, engine):
        engine.metrics.kernel_qblock_rounded = 0
        reqs = mixed_requests(engine, MIXED[:2])
        run_trace(engine, reqs, prefill_chunk=4,
                  attn_backend="pallas_paged", kv_page_size=4,
                  kernel_tune="2,1")
        assert engine.metrics.kernel_qblock_rounded == 0


class TestTuneKernel:
    def test_returns_candidate_winner(self, engine):
        _KERNEL_TUNE_CACHE.clear()
        res = tune_kernel(engine.cfg, 4, 4, interpret=True, repeats=1,
                          pages_per_step=(1, 2))
        assert res["q_block"] in (1, 2, 4)
        assert res["pages_per_step"] in (1, 2)
        assert not res["cached"]
        assert res["best_ms"] == min(t[2] for t in res["timings"])
        assert len(res["timings"]) == 6      # divisors(4) x pps(2)

    def test_memoised_per_key(self, engine):
        res1 = tune_kernel(engine.cfg, 4, 4, interpret=True, repeats=1,
                           pages_per_step=(1, 2))
        res2 = tune_kernel(engine.cfg, 4, 4, interpret=True, repeats=1,
                           pages_per_step=(1, 2))
        assert res2["cached"] and res2["q_block"] == res1["q_block"]
        # a different Q is a different launch point
        res3 = tune_kernel(engine.cfg, 4, 2, interpret=True, repeats=1,
                           pages_per_step=(1,), q_blocks=(2,))
        assert not res3["cached"] and res3["key"] != res1["key"]

    def test_serve_auto_matches_off(self, engine, baseline):
        """The full wiring: --kernel-tune auto serves token-identically
        to the identity layout."""
        reqs, want = baseline
        got = run_trace(engine, reqs, prefill_chunk=4,
                        attn_backend="pallas_paged", kv_page_size=4,
                        kernel_tune="auto")
        assert_tokens_identical(got, want, "kernel_tune=auto")

    def test_rejects_bad_spec(self, engine):
        with pytest.raises(ValueError, match="kernel_tune"):
            Scheduler(engine, attn_backend="pallas_paged", kv_page_size=4,
                      kernel_tune="fastest")
        with pytest.raises(ValueError, match="pallas_paged"):
            Scheduler(engine, kernel_tune="auto")
