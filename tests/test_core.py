"""Unit + property tests for the compression core (paper §III)."""

import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import bitpack, clustering, compression, frequency, huffman
from tests.conftest import skewed_sequences


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

class TestBitpack:
    def test_kernel_sequence_roundtrip(self, rng):
        w = rng.integers(0, 2, size=(8, 32, 3, 3), dtype=np.uint8)
        seqs = bitpack.kernel_to_sequences(w)
        assert seqs.shape == (8, 32) and seqs.max() < 512
        assert np.array_equal(bitpack.sequences_to_kernel(seqs), w)

    def test_natural_mapping(self):
        w = np.zeros((1, 1, 3, 3), dtype=np.uint8)
        assert bitpack.kernel_to_sequences(w)[0, 0] == 0
        w[:] = 1
        assert bitpack.kernel_to_sequences(w)[0, 0] == 511
        w = np.zeros((1, 1, 3, 3), dtype=np.uint8)
        w[0, 0, 0, 0] = 1            # position (0,0) -> MSB (paper Fig. 2)
        assert bitpack.kernel_to_sequences(w)[0, 0] == 256

    def test_channel_pack_conv_roundtrip(self, rng):
        w = rng.integers(0, 2, size=(4, 64, 3, 3), dtype=np.uint8)
        packed = bitpack.channel_pack_conv(w)
        assert packed.shape == (4, 2, 9)
        assert np.array_equal(bitpack.channel_unpack_conv(packed), w)

    @given(st.integers(1, 5), st.integers(1, 700))
    @settings(max_examples=25, deadline=None)
    def test_gemm_roundtrip(self, n, k):
        rng = np.random.default_rng(n * 1000 + k)
        bits = rng.integers(0, 2, size=(n, k), dtype=np.uint8)
        seqs = bitpack.gemm_to_sequences(bits)
        assert np.array_equal(bitpack.sequences_to_gemm(seqs, k), bits)
        packed = bitpack.pack_gemm_operand(bits)
        assert np.array_equal(bitpack.unpack_gemm_operand(packed, k), bits)


# ---------------------------------------------------------------------------
# huffman (simplified 4-node coder)
# ---------------------------------------------------------------------------

class TestHuffman:
    def test_code_lengths_match_paper(self, rng):
        hist = frequency.sequence_histogram(skewed_sequences(rng, 20000))
        assign = huffman.assign_nodes(hist)
        _, lens = assign.code_of(np.arange(512))
        assert set(np.unique(lens)) <= {6, 8, 9, 12}   # paper §VI
        # top-32 sequences must receive 6-bit codes
        top32 = frequency.ranked_sequences(hist)[:32]
        assert (lens[top32] == 6).all()

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4000))
    @settings(max_examples=20, deadline=None)
    def test_stream_roundtrip(self, seed, n):
        rng = np.random.default_rng(seed)
        vals = skewed_sequences(rng, n)
        assign = huffman.assign_nodes(frequency.sequence_histogram(vals))
        words, nbits = huffman.encode_stream(vals, assign)
        dec = huffman.decode_stream(words, nbits, assign, count=n)
        assert np.array_equal(dec, vals)

    def test_simplified_never_beats_full_huffman(self, rng):
        hist = frequency.sequence_histogram(skewed_sequences(rng, 30000))
        assign = huffman.assign_nodes(hist)
        assert assign.avg_bits(hist) >= huffman.full_huffman_avg_bits(hist)

    def test_paper_ratio_arithmetic(self, rng):
        """Feeding the paper's measured node frequencies reproduces the
        published compression ratios (claims C2/C3)."""
        h_enc = frequency.synthetic_histogram(
            (0.46, 0.24, 0.23, 0.05), 300_000, rng)
        r_enc = huffman.assign_nodes(h_enc).compression_ratio(h_enc)
        assert 1.18 <= r_enc <= 1.27, r_enc              # paper: 1.18-1.25
        h_cl = frequency.synthetic_histogram(
            (0.65, 0.25, 0.08, 0.006), 300_000, rng)
        r_cl = huffman.assign_nodes(h_cl).compression_ratio(h_cl)
        assert 1.29 <= r_cl <= 1.37, r_cl                # paper: 1.30-1.36


# ---------------------------------------------------------------------------
# clustering (paper §III-C)
# ---------------------------------------------------------------------------

class TestClustering:
    def test_hamming_invariant(self, rng):
        vals = skewed_sequences(rng, 20000)
        _, repl = clustering.apply_clustering(vals)
        assert clustering.max_weight_flips(repl) <= 1

    def test_replacements_target_top_m(self, rng):
        vals = skewed_sequences(rng, 20000)
        hist = frequency.sequence_histogram(vals)
        repl = clustering.build_replacement_map(hist, m=64, n=256)
        changed = np.nonzero(repl != np.arange(512))[0]
        top = set(frequency.ranked_sequences(hist)[:64].tolist())
        assert all(int(repl[c]) in top for c in changed)

    def test_clustering_improves_ratio(self, rng):
        vals = skewed_sequences(rng, 40000)
        before = compression.compress_sequences(vals, vals.shape, "gemm",
                                                cluster=False)
        after = compression.compress_sequences(vals, vals.shape, "gemm",
                                               cluster=True)
        assert after.ratio_stream() >= before.ratio_stream()

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_replacement_map_is_projection(self, seed):
        rng = np.random.default_rng(seed)
        hist = frequency.sequence_histogram(skewed_sequences(rng, 3000))
        repl = clustering.build_replacement_map(hist)
        # applying twice == applying once (targets are never remapped)
        assert np.array_equal(repl[repl], repl)


# ---------------------------------------------------------------------------
# end-to-end compression artifacts
# ---------------------------------------------------------------------------

class TestCompression:
    def test_conv_lossless_without_clustering(self, rng):
        w = rng.integers(0, 2, size=(16, 64, 3, 3), dtype=np.uint8)
        ct = compression.compress_conv3x3(w, cluster=False)
        assert np.array_equal(compression.decompress(ct), w)

    def test_tiled_matches_stream(self, rng):
        vals = skewed_sequences(rng, 5000)
        ct = compression.compress_sequences(vals, vals.shape, "gemm",
                                            cluster=False)
        ts = ct.tiled
        for ti in range(ts.n_tiles):
            for si in range(0, ts.s, 31):
                dec = huffman.decode_stream(
                    np.ascontiguousarray(ts.words[ti, :, si]),
                    ts.w * 32, ct.assign, count=ts.c)
                idx = ti * ts.s * ts.c + np.arange(ts.c) * ts.s + si
                exp = np.where(idx < len(vals),
                               vals[np.minimum(idx, len(vals) - 1)], 0)
                assert np.array_equal(dec, exp)

    def test_fused_layout_roundtrip(self, rng):
        bits = rng.integers(0, 2, size=(40, 700), dtype=np.uint8)
        fc = compression.compress_gemm_fused(bits, cluster=False)
        assert np.array_equal(compression.decompress_fused(fc), bits)

    def test_model_report(self, rng):
        # skewed kernels -> binary ratio > 1; model ratio between 1 and
        # binary ratio (paper: 1.32x kernels, 1.2x model)
        seqs = skewed_sequences(rng, 16 * 64).reshape(16, 64)
        w = bitpack.sequences_to_kernel(seqs)
        tensors = {"block0/w3": w}
        _, rep = compression.compress_model(tensors, fp_bits=w.size // 4)
        assert rep.binary_ratio > 1.1
        assert 1.0 < rep.model_ratio < rep.binary_ratio
