"""Shared scheduler test harness: engine factory + trace runner +
token-equivalence assertion.

Every serving suite used to carry its own copy of this boilerplate
(engine construction over the reduced configs, a submit/run/collect loop,
and a dict-equality check); it lives here once so a new suite — or a new
serving feature like prefix sharing — tests token equivalence against the
oracle in three lines.  ``run_trace`` returns ``{request index: generated
token tuple}`` keyed by submission order, so two runs over the same
request list compare directly regardless of scheduling order.
"""

import jax
import numpy as np

from repro.models.api import get_model
from repro.runtime import Scheduler, ServeEngine
from tests.test_models import reduced

# the canonical mixed-length (prompt_len, gen) trace: short/long prompts
# and budgets interleaved so admission, chunking, paging, and retire all
# overlap (suites that need a smaller trace slice it)
MIXED = [(5, 7), (12, 2), (20, 5), (6, 9), (3, 1), (9, 4)]


def make_engine(arch="minitron-8b", seed=0, **engine_kw):
    """ServeEngine over a reduced config with compressed MLPs."""
    cfg = reduced(arch)
    params = jax.tree_util.tree_map(
        np.asarray, get_model(cfg).init_params(cfg, jax.random.PRNGKey(seed)))
    return ServeEngine(cfg, params, compress=True, **engine_kw)


def mixed_requests(engine, trace=MIXED, seed=7):
    """Deterministic (prompt, gen) pairs for a (prompt_len, gen) trace."""
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, engine.cfg.vocab_size, L), g)
            for L, g in trace]


def run_trace(engine, reqs, **kw):
    """Serve ``reqs`` through a fresh Scheduler -> {request index:
    generated token tuple}, keyed by submission order."""
    kw.setdefault("batch_size", 2)
    kw.setdefault("buckets", (32,))
    sched = Scheduler(engine, **kw)
    rids = {}
    for i, r in enumerate(reqs):
        rids[sched.submit(*r).rid] = i
    done = sched.run()
    assert len(done) == len(reqs), (len(done), len(reqs))
    return {rids[r.rid]: tuple(r.generated) for r in done}


def assert_tokens_identical(got, want, label=""):
    """Per-request token equality with a readable first-divergence
    message (dict inequality alone points at nothing)."""
    assert set(got) == set(want), \
        f"{label}: request sets differ: {sorted(got)} vs {sorted(want)}"
    for i in sorted(want):
        assert got[i] == want[i], \
            f"{label}: request {i} diverged:\n  got  {got[i]}\n" \
            f"  want {want[i]}"
