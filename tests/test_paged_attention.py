"""The attention-backend seam: ``pallas_paged`` in-kernel decode attention
must be token-identical to the ``gathered`` reference across archs
(plain GQA / rolling-window gemma2 / MLA deepseek), page sizes
(1, 4, odd), chunked prefill, wave mode, and mid-decode pool growth —
and the kernel itself must match ``attention.decode_attention`` on random
page tables including the page-0 dummy sink.  The kernel backend's hot
loop must also move zero gather/scatter bytes (the acceptance metric for
killing the per-step page copies)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import paged_decode_attention
from repro.models.api import supports_paged_attention
from repro.models.attention import decode_attention
from repro.runtime import Scheduler
from tests.harness import MIXED, make_engine, mixed_requests
from tests.harness import run_trace as serve

pytestmark = pytest.mark.pallas   # CI kernels-interpret job runs these


# ---------------------------------------------------------------------------
# kernel unit tests vs the decode_attention oracle
# ---------------------------------------------------------------------------

def random_paged_cache(rng, s, kh, d, dv, page, pages_per_slot,
                       n_pages=None):
    """Random pools + a shuffled page table whose tail rows point at the
    page-0 dummy sink (exactly the scheduler's layout contract)."""
    lengths = rng.integers(1, pages_per_slot * page + 1, s).astype(np.int32)
    need = int(sum(-(-int(ln) // page) for ln in lengths))
    n_pages = n_pages or need + 3                    # spare pages + dummy
    assert n_pages > need
    k_pages = rng.standard_normal((n_pages, page, kh, d)).astype(np.float32)
    v_pages = rng.standard_normal((n_pages, page, kh, dv)).astype(np.float32)
    ids = list(range(1, n_pages))
    rng.shuffle(ids)
    it = iter(ids)
    table = np.zeros((s, pages_per_slot), np.int32)  # 0 = dummy sink
    for i in range(s):
        for j in range(-(-int(lengths[i]) // page)):
            table[i, j] = next(it)
    return k_pages, v_pages, table, lengths


def gather_reference(q, k_pages, v_pages, table, lengths, **kw):
    """The gathered oracle: contiguous per-slot views + decode_attention.

    ``q`` is raw (decode_attention applies the 1/sqrt(d) scale itself; the
    kernel takes pre-scaled queries — callers scale only the kernel's)."""
    s, h, d = q.shape
    page = k_pages.shape[1]
    kh, dv = k_pages.shape[2], v_pages.shape[-1]
    smax = table.shape[1] * page
    k_view = k_pages[table].reshape(s, smax, kh, d)
    v_view = v_pages[table].reshape(s, smax, kh, dv)
    return decode_attention(jnp.asarray(q[:, None]), jnp.asarray(k_view),
                            jnp.asarray(v_view),
                            jnp.asarray(lengths - 1), **kw)[:, 0]


class TestKernelVsOracle:
    @pytest.mark.parametrize("page,pages_per_slot", [(1, 8), (3, 4), (4, 3),
                                                     (8, 2)])
    def test_random_tables_incl_dummy_sink(self, page, pages_per_slot):
        rng = np.random.default_rng(page)
        s, h, kh, d, dv = 4, 4, 2, 16, 16
        k_pages, v_pages, table, lengths = random_paged_cache(
            rng, s, kh, d, dv, page, pages_per_slot)
        q = rng.standard_normal((s, h, d)).astype(np.float32)
        out = paged_decode_attention(
            jnp.asarray(q) * d ** -0.5, jnp.asarray(k_pages),
            jnp.asarray(v_pages), jnp.asarray(table), jnp.asarray(lengths),
            interpret=True)
        want = gather_reference(q, k_pages, v_pages, table, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window,softcap", [(5, 0.0), (0, 4.0),
                                                (7, 3.0)])
    def test_window_and_softcap(self, window, softcap):
        rng = np.random.default_rng(11)
        s, h, kh, d = 3, 4, 1, 8
        k_pages, v_pages, table, lengths = random_paged_cache(
            rng, s, kh, d, d, 4, 4)
        q = rng.standard_normal((s, h, d)).astype(np.float32)
        out = paged_decode_attention(
            jnp.asarray(q) * d ** -0.5, jnp.asarray(k_pages),
            jnp.asarray(v_pages), jnp.asarray(table), jnp.asarray(lengths),
            window=window, softcap_val=softcap, interpret=True)
        want = gather_reference(q, k_pages, v_pages, table, lengths,
                                window=window, attn_softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_mla_second_operand(self):
        """(q, k) + (q2, k2) scoring with a shared post-sum scale — the MLA
        absorbed-decode form (latent pool doubles as the value pool)."""
        rng = np.random.default_rng(5)
        s, h, r, dr, page, pps = 3, 4, 8, 4, 3, 4
        c_pages, _, table, lengths = random_paged_cache(
            rng, s, 1, r, r, page, pps)
        pe_pages = rng.standard_normal(
            (c_pages.shape[0], page, 1, dr)).astype(np.float32)
        q1 = rng.standard_normal((s, h, r)).astype(np.float32)
        q2 = rng.standard_normal((s, h, dr)).astype(np.float32)
        scale = (r + dr) ** -0.5
        out = paged_decode_attention(
            jnp.asarray(q1), jnp.asarray(c_pages), jnp.asarray(c_pages),
            jnp.asarray(table), jnp.asarray(lengths), jnp.asarray(q2),
            jnp.asarray(pe_pages), scale=scale, interpret=True)
        smax = pps * page
        for i in range(s):
            c = c_pages[table[i], :, 0].reshape(smax, r)
            pe = pe_pages[table[i], :, 0].reshape(smax, dr)
            sc = (q1[i] @ c.T + q2[i] @ pe.T) * scale
            sc = np.where(np.arange(smax)[None] < lengths[i], sc, -1e30)
            p = np.asarray(jax.nn.softmax(jnp.asarray(sc), axis=-1))
            np.testing.assert_allclose(np.asarray(out[i]), p @ c,
                                       rtol=2e-5, atol=2e-5)

    def test_dummy_sink_never_contaminates(self):
        """Poisoning the page-0 dummy sink with huge values must not
        change any output: every position the mask admits has a real
        page, so the sink is never read as a valid key."""
        rng = np.random.default_rng(9)
        s, h, kh, d = 3, 4, 2, 8
        k_pages, v_pages, table, lengths = random_paged_cache(
            rng, s, kh, d, d, 4, 4)
        q = rng.standard_normal((s, h, d)).astype(np.float32)

        def run(kp, vp):
            return np.asarray(paged_decode_attention(
                jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(table), jnp.asarray(lengths), interpret=True))

        clean = run(k_pages, v_pages)
        k_pages[0] = 1e6
        v_pages[0] = -1e6
        poisoned = run(k_pages, v_pages)
        assert np.isfinite(poisoned).all()
        np.testing.assert_array_equal(clean, poisoned)


# ---------------------------------------------------------------------------
# backend seam: token-identical serving across archs / page sizes
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    return make_engine()


@pytest.fixture(scope="module")
def baseline(engine):
    reqs = mixed_requests(engine, MIXED[:4])
    return reqs, serve(engine, reqs)


class TestBackendTokenEquivalence:
    @pytest.mark.parametrize("page", [1, 4, 5])
    def test_kernel_backend_any_page_size(self, engine, baseline, page):
        """pallas_paged == gathered for page sizes 1, 4, and odd."""
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=page,
                     attn_backend="pallas_paged") == base

    def test_kernel_backend_matches_gathered_paged(self, engine, baseline):
        """Three-way: monolithic lanes == gathered pages == in-kernel."""
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=4) == base
        assert serve(engine, reqs, kv_page_size=4,
                     attn_backend="pallas_paged") == base

    def test_kernel_backend_with_chunked_prefill(self, engine, baseline):
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=4, prefill_chunk=3,
                     attn_backend="pallas_paged") == base

    def test_kernel_backend_wave_mode(self, engine, baseline):
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=8, mode="wave",
                     attn_backend="pallas_paged") == base

    @pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-v2-236b"])
    def test_rolling_window_and_mla_archs(self, arch):
        """gemma2: rolling-window lanes run the reference path next to
        paged global layers in the same step; deepseek: MLA absorbed
        decode through the kernel's second score operand."""
        engine = make_engine(arch)
        rng = np.random.default_rng(3)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, L), g)
                for L, g in [(20, 6), (4, 3), (11, 8)]]
        base = serve(engine, reqs)
        assert serve(engine, reqs, kv_page_size=4,
                     attn_backend="pallas_paged") == base
        assert serve(engine, reqs, kv_page_size=3,
                     attn_backend="pallas_paged") == base

    def test_requires_page_size(self, engine):
        with pytest.raises(ValueError, match="kv_page_size"):
            Scheduler(engine, attn_backend="pallas_paged")

    def test_unknown_backend_rejected(self, engine):
        with pytest.raises(ValueError, match="backend"):
            Scheduler(engine, kv_page_size=4, attn_backend="flash3")

    def test_recurrent_arch_falls_back_with_note(self):
        """The backend downgrade warns (warn-once per family); the
        trigger rides inside ``pytest.warns`` so the escaped-warning
        escalation in pyproject.toml stays clean."""
        from repro.runtime import scheduler as sched_mod

        engine = make_engine("recurrentgemma-2b")
        assert not supports_paged_attention(engine.cfg)
        notes = []
        sched_mod._FALLBACK_WARNED.clear()     # deterministic first hit
        with pytest.warns(RuntimeWarning,
                          match="supports_paged_attention=False"):
            sched = Scheduler(engine, kv_page_size=4,
                              attn_backend="pallas_paged",
                              emit=notes.append)
        assert sched.attn_backend == "gathered"
        assert any("gathered" in n for n in notes)


class TestKernelBackendHotPath:
    def test_zero_gather_bytes_on_decode_path(self, engine, baseline):
        """The acceptance metric: under pallas_paged the decode hot loop
        performs no per-step page gather/scatter copies at all, while the
        gathered backend moves two full view copies per step."""
        reqs, base = baseline
        engine.metrics = type(engine.metrics)()
        assert serve(engine, reqs, kv_page_size=4,
                     attn_backend="pallas_paged") == base
        m = engine.metrics
        assert m.kv_gather_bytes == 0
        assert m.kv_gather_bytes_avoided > 0
        engine.metrics = type(engine.metrics)()
        serve(engine, reqs, kv_page_size=4)
        m = engine.metrics
        assert m.kv_gather_bytes > 0
        assert m.kv_gather_bytes_avoided == 0

    def test_grow_pages_mid_decode_no_recompile(self, engine):
        """Growing the logical pool within page_capacity mid-serving must
        not touch the compiled paged decode step and must keep tokens
        correct."""
        rng = np.random.default_rng(2)
        sched = Scheduler(engine, batch_size=2, buckets=(16,),
                          kv_page_size=4, kv_pages=5, kv_page_capacity=16,
                          attn_backend="pallas_paged")
        prompts = [rng.integers(0, engine.cfg.vocab_size, 8)
                   for _ in range(3)]
        sched.submit(prompts[0], 6)
        out1 = sched.run()
        assert len(out1) == 1
        key = (sched._pool.paged_flags, sched._pool.page_size, 1, False,
               0, 1)
        c0 = engine._mixed_jits[key]._cache_size()
        sched._pool.grow_pages(9)
        sched.submit(prompts[1], 6)
        sched.submit(prompts[2], 6)
        out2 = sched.run()
        assert len(out2) == 2
        assert engine._mixed_jits[key]._cache_size() == c0
        assert sched._pool.allocator.n_allocated == 0
        # identical prompts generate identical tokens before/after growth
        ref = serve(engine, [(prompts[0], 6)], buckets=(16,))
        assert tuple(out1[0].generated) == ref[0]

    def test_no_pages_leaked_after_retire(self, engine, baseline):
        reqs, _ = baseline
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=4, attn_backend="pallas_paged")
        for r in reqs:
            sched.submit(*r)
        sched.run()
        pool = sched._pool
        assert pool.allocator.n_allocated == 0
        assert pool.allocator.reserved == 0
        assert (pool.table == 0).all()
