"""Per-kernel allclose tests vs the ref.py oracles (interpret mode on CPU),
with shape/dtype sweeps + hypothesis properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import bitpack, compression
from repro.kernels import ops, ref
from repro.kernels.huffman_decode import pack_bitplane_tables
from tests.conftest import skewed_sequences

pytestmark = pytest.mark.pallas   # CI kernels-interpret job runs these


class TestBinaryContraction:
    @pytest.mark.parametrize("m,n,k", [
        (1, 1, 9), (7, 5, 100), (64, 32, 288), (130, 70, 600),
        (33, 129, 1024),
    ])
    def test_shapes_vs_oracle(self, rng, m, n, k):
        x = rng.standard_normal((m, k)).astype(np.float32)
        w = rng.standard_normal((n, k)).astype(np.float32)
        out = ops.binary_matmul(jnp.asarray(x), jnp.asarray(w))
        exp = ref.binary_matmul(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_dtypes(self, rng, dtype):
        x = rng.standard_normal((16, 100)).astype(dtype)
        w = rng.standard_normal((8, 100)).astype(dtype)
        out = ops.binary_matmul(jnp.asarray(x), jnp.asarray(w))
        exp = ref.binary_matmul(jnp.asarray(x.astype(np.float32)),
                                jnp.asarray(w.astype(np.float32)))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    @given(st.integers(0, 10_000), st.integers(1, 40), st.integers(1, 300))
    @settings(max_examples=15, deadline=None)
    def test_dot_range_property(self, seed, m, k):
        """|dot| <= k and dot == k (mod 2) — xnor-popcount invariants."""
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((m, k)).astype(np.float32)
        w = rng.standard_normal((4, k)).astype(np.float32)
        out = np.asarray(ops.binary_matmul(jnp.asarray(x), jnp.asarray(w)))
        assert (np.abs(out) <= k).all()
        assert ((out.astype(np.int64) - k) % 2 == 0).all()


class TestHuffmanDecodeKernel:
    @pytest.mark.parametrize("n", [100, 1024, 5000])
    @pytest.mark.parametrize("gather", ["onehot", "bitplane"])
    def test_vs_sequences(self, rng, n, gather):
        vals = skewed_sequences(rng, n)
        ct = compression.compress_sequences(vals, (n,), "gemm",
                                            cluster=False)
        ts = ct.tiled
        tabs = ct.decode_tables()
        if gather == "bitplane":
            tabs = pack_bitplane_tables(tabs)
        seqs = ops.decode_sequences(
            jnp.asarray(ts.words), jnp.asarray(tabs), c=ts.c,
            n_seqs=ts.n_seqs, gather=gather)
        np.testing.assert_array_equal(np.asarray(seqs),
                                      vals.astype(np.int32))

    def test_random_uniform_sequences(self, rng):
        """Uniform (incompressible) input exercises the escape node."""
        vals = rng.integers(0, 512, size=2048, dtype=np.uint16)
        ct = compression.compress_sequences(vals, (2048,), "gemm",
                                            cluster=False)
        seqs = ops.decode_sequences(
            jnp.asarray(ct.tiled.words), jnp.asarray(ct.decode_tables()),
            c=ct.tiled.c, n_seqs=2048)
        np.testing.assert_array_equal(np.asarray(seqs),
                                      vals.astype(np.int32))


class TestFusedDecodeMatmul:
    @pytest.mark.parametrize("m,n,k", [(4, 10, 100), (33, 45, 700),
                                       (65, 64, 576)])
    @pytest.mark.parametrize("cluster", [False, True])
    def test_vs_oracle(self, rng, m, n, k, cluster):
        x = rng.standard_normal((m, k)).astype(np.float32)
        wbits = rng.integers(0, 2, size=(n, k), dtype=np.uint8)
        words, tabs, meta = ops.prepare_compressed_gemm(wbits,
                                                        cluster=cluster)
        out = ops.compressed_binary_matmul(
            jnp.asarray(x), words, tabs, k_true=k, n_true=n)
        wrec = compression.decompress_fused(
            compression.compress_gemm_fused(wbits, cluster=cluster))
        exp = ref.binary_matmul(
            jnp.asarray(x), jnp.asarray(wrec.astype(np.float32) * 2 - 1))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_bitplane_gather_equals_onehot(self, rng):
        x = rng.standard_normal((16, 288)).astype(np.float32)
        wbits = rng.integers(0, 2, size=(32, 288), dtype=np.uint8)
        outs = []
        for gather in ("onehot", "bitplane"):
            words, tabs, meta = ops.prepare_compressed_gemm(
                wbits, cluster=False, gather=gather)
            outs.append(np.asarray(ops.compressed_binary_matmul(
                jnp.asarray(x), words, tabs, k_true=288, n_true=32,
                gather=gather)))
        np.testing.assert_array_equal(outs[0], outs[1])


class TestBinaryConv:
    @pytest.mark.parametrize("hw,cin,cout,stride", [
        ((8, 8), 32, 16, 1), ((9, 11), 64, 20, 2), ((5, 5), 96, 8, 1),
    ])
    def test_vs_reference_conv(self, rng, hw, cin, cout, stride):
        x = rng.standard_normal((2, *hw, cin)).astype(np.float32)
        w = rng.standard_normal((cout, cin, 3, 3)).astype(np.float32)
        out = ops.binary_conv3x3(jnp.asarray(x), jnp.asarray(w),
                                 stride=stride)
        exp = ref.binary_conv3x3(jnp.asarray(x), jnp.asarray(w),
                                 stride=stride)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_compressed_conv(self, rng):
        x = rng.standard_normal((1, 6, 6, 64)).astype(np.float32)
        w = rng.standard_normal((24, 64, 3, 3)).astype(np.float32)
        words, tabs, meta = ops.prepare_compressed_conv(
            bitpack.to_bits(w), cluster=False)
        out = ops.compressed_binary_conv3x3(
            jnp.asarray(x), words, tabs, cin=64, cout=24)
        exp = ref.binary_conv3x3(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
        assert meta["ratio_stream"] > 0.5       # random weights barely move


class TestPackingMirrors:
    @given(st.integers(0, 10_000), st.integers(1, 8), st.integers(9, 600))
    @settings(max_examples=15, deadline=None)
    def test_runtime_pack_equals_offline(self, seed, m, k):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((m, k)).astype(np.float32)
        jnp_packed = np.asarray(ref.binarize_pack(jnp.asarray(x)))
        np_packed = bitpack.pack_gemm_operand(bitpack.to_bits(x))
        assert np.array_equal(jnp_packed, np_packed)


class TestBinarizePackKernel:
    @pytest.mark.parametrize("m,k", [(1, 9), (7, 100), (33, 288),
                                     (130, 600), (513, 1000)])
    def test_vs_oracle(self, rng, m, k):
        x = rng.standard_normal((m, k)).astype(np.float32)
        got = ops.binarize_pack(jnp.asarray(x), use_kernel=True)
        want = ref.binarize_pack(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_feeds_contraction(self, rng):
        """Kernel-packed activations through the packed GEMM end-to-end."""
        x = rng.standard_normal((20, 400)).astype(np.float32)
        w = rng.standard_normal((12, 400)).astype(np.float32)
        xw = ops.binarize_pack(jnp.asarray(x), use_kernel=True)
        ww = ops.binarize_pack(jnp.asarray(w), use_kernel=True)
        out = ops.binary_matmul_packed(xw, ww, 400)
        exp = ref.binary_matmul(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_array_equal(
            np.asarray(out).astype(np.float32), np.asarray(exp))
