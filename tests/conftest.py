import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def skewed_sequences(rng, n: int, peak: float = 300.0) -> np.ndarray:
    """Sequence sample with a ReActNet-like skewed histogram."""
    probs = np.ones(512)
    probs[0] = peak
    probs[511] = peak * 0.7
    for v in (1, 7, 73, 255, 448):
        probs[v] = peak * 0.3
    probs /= probs.sum()
    return rng.choice(512, size=n, p=probs).astype(np.uint16)
