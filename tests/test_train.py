"""Training substrate tests: optimizer, loss-decrease, gradient compression,
fault tolerance (bad-step containment, straggler detection)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding as shd
from repro.dist.compression_comm import (compress_grads,
                                         init_error_feedback)
from repro.dist.fault import FaultConfig, Supervisor
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_model
from repro.train import optimizer as opt
from tests.test_models import REDUCED, make_batch, reduced


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        oc = opt.OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                           total_steps=100)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init_state(params)
        for _ in range(100):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.apply_updates(params, grads, state, oc)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clip_and_schedule(self):
        oc = opt.OptConfig(lr=1.0, grad_clip=1.0, warmup_steps=10,
                           total_steps=100)
        sched = opt.lr_schedule(oc)
        assert float(sched(jnp.int32(0))) < float(sched(jnp.int32(10)))
        assert float(sched(jnp.int32(100))) < float(sched(jnp.int32(10)))
        params = {"w": jnp.zeros(3)}
        state = opt.init_state(params)
        _, _, metrics = opt.apply_updates(
            params, {"w": jnp.full(3, 1e6)}, state, oc)
        assert float(metrics["grad_norm"]) > 1e5   # measured pre-clip

    def test_latent_clip(self):
        oc = opt.OptConfig(lr=10.0, clip_latent=1.5, warmup_steps=0,
                           weight_decay=0.0)
        params = {"w": jnp.array([1.4])}
        state = opt.init_state(params)
        params, _, _ = opt.apply_updates(params, {"w": jnp.array([-9.9])},
                                         state, oc)
        assert float(params["w"][0]) <= 1.5


class TestTrainLoop:
    def test_tiny_lm_loss_decreases(self):
        """Overfit one batch through the full jit'd step (sharded params,
        chunked CE, AdamW): loss must fall fast and monotonically-ish."""
        cfg = reduced("h2o-danube-1.8b")
        mesh = make_host_mesh()
        oc = opt.OptConfig(lr=3e-3, warmup_steps=0, total_steps=200,
                           weight_decay=0.0)
        with shd.use_mesh(mesh):
            step_fn, _ = steps_mod.build_train_step(cfg, mesh, oc,
                                                    donate=False)
            state = steps_mod.init_train_state(cfg, mesh,
                                               jax.random.PRNGKey(0))
            data = SyntheticLM(cfg.vocab_size, 8, 64)
            batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
            losses = []
            for _ in range(25):
                state, loss = step_fn(state, batch)
                losses.append(float(loss))
        assert losses[-1] < losses[0] - 2.0, losses


class TestGradCompression:
    def _run(self, mode):
        mesh = make_host_mesh()
        g = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((64, 32)).astype(np.float32))}
        ef = init_error_feedback(g)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def f(gr, e):
            return compress_grads(gr, e, ("data",), mode=mode)

        specs = jax.tree_util.tree_map(lambda _: P(), g)
        out, new_ef = shard_map(f, mesh=mesh, in_specs=(specs, specs),
                                out_specs=(specs, specs),
                                check_rep=False)(g, ef)
        return g, out, new_ef

    @pytest.mark.parametrize("mode", ["onebit", "int8"])
    def test_signs_and_error_feedback(self, mode):
        g, out, ef = self._run(mode)
        # compressed result has the right signs (single replica = own signs)
        s_in = np.sign(np.asarray(g["w"]))
        s_out = np.sign(np.asarray(out["w"]))
        frac = (s_in == s_out).mean()
        assert frac > 0.95
        # error feedback holds the residual: g = out + ef
        np.testing.assert_allclose(np.asarray(out["w"] + ef["w"]),
                                   np.asarray(g["w"]), rtol=1e-4, atol=1e-4)

    def test_error_feedback_converges(self):
        """Repeated compression of a constant gradient recovers its mean
        magnitude on average (EF eliminates bias over steps)."""
        g = jnp.asarray(np.random.default_rng(1)
                        .standard_normal(4096).astype(np.float32))
        ef = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        from repro.dist.compression_comm import onebit_allreduce
        mesh = make_host_mesh()
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def step(e):
            return onebit_allreduce(g, e, ("data",))

        for _ in range(60):
            out, ef = shard_map(step, mesh=mesh, in_specs=(P(),),
                                out_specs=(P(), P()),
                                check_rep=False)(ef)
            acc = acc + out
        # per-step emission magnitude is the mean |g| scale, so EF can
        # de-bias everything whose magnitude fits under it; the tail above
        # the scale saturates by construction (signSGD property)
        got = np.asarray(acc / 60)
        want = np.asarray(g)
        mask = np.abs(want) <= 1.0
        assert mask.mean() > 0.5
        np.testing.assert_allclose(got[mask], want[mask], atol=0.15)


class TestFaultTolerance:
    def test_bad_step_containment(self):
        sup = Supervisor(FaultConfig(max_consecutive_bad=3))
        state = {"w": jnp.zeros(2)}

        calls = {"n": 0}

        def step_fn(s, b):
            calls["n"] += 1
            loss = jnp.asarray(np.nan if b["bad"] else 1.0)
            return {"w": s["w"] + 1}, loss

        state, rep = sup.run_step(step_fn, state, {"bad": True}, 0)
        assert rep.skipped and float(state["w"][0]) == 0.0   # update dropped
        state, rep = sup.run_step(step_fn, state, {"bad": False}, 1)
        assert not rep.skipped and float(state["w"][0]) == 1.0

    def test_consecutive_bad_aborts(self):
        sup = Supervisor(FaultConfig(max_consecutive_bad=2))
        step_fn = lambda s, b: (s, jnp.asarray(np.nan))
        state = {}
        state, _ = sup.run_step(step_fn, state, {}, 0)
        with pytest.raises(RuntimeError, match="consecutive bad"):
            sup.run_step(step_fn, state, {}, 1)

    def test_straggler_detection(self):
        import time
        sup = Supervisor(FaultConfig(straggler_factor=3.0))
        fast = lambda s, b: (s, jnp.asarray(1.0))

        def slow(s, b):
            time.sleep(0.25)
            return s, jnp.asarray(1.0)

        state = {}
        for i in range(6):
            state, rep = sup.run_step(fast, state, {}, i)
        state, rep = sup.run_step(slow, state, {}, 6)
        assert rep.straggler and any("straggler" in e for e in sup.events)


class TestDataPipeline:
    def test_determinism_and_host_sharding(self):
        a = SyntheticLM(1000, 16, 32, seed=7, host_id=0, num_hosts=4)
        b = SyntheticLM(1000, 16, 32, seed=7, host_id=0, num_hosts=4)
        assert np.array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
        c = SyntheticLM(1000, 16, 32, seed=7, host_id=1, num_hosts=4)
        assert not np.array_equal(a.batch(5)["tokens"],
                                  c.batch(5)["tokens"])
        assert a.batch(0)["tokens"].shape == (4, 32)

    def test_labels_learnable_map(self):
        d = SyntheticLM(1000, 4, 16)
        b = d.batch(0)
        prev = np.roll(b["tokens"], 1, axis=1)
        prev[:, 0] = 0
        assert np.array_equal(b["labels"],
                              (5 * b["tokens"] + 3 + prev) % 1000)
