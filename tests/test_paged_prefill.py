"""Chunked prefill + paged KV lanes: token equivalence against the
monolithic PR-2 paths, page-allocator invariants (no leak, no double
allocation, cross-slot isolation), and pool growth without decode
recompiles."""

import numpy as np
import pytest

from repro.models.api import supports_chunked_prefill
from repro.runtime import PageAllocator, Scheduler
from tests.harness import make_engine, mixed_requests
from tests.harness import run_trace as serve


@pytest.fixture(scope="module")
def engine():
    return make_engine()


@pytest.fixture(scope="module")
def baseline(engine):
    """Monolithic-prefill, monolithic-lane tokens (the PR-2 path)."""
    reqs = mixed_requests(engine)
    return reqs, serve(engine, reqs)


class TestTokenEquivalence:
    @pytest.mark.parametrize("chunk", [1, 3, 5, 64])
    def test_chunked_prefill_any_chunk_size(self, engine, baseline, chunk):
        reqs, base = baseline
        assert serve(engine, reqs, prefill_chunk=chunk) == base

    @pytest.mark.parametrize("page", [4, 8, 16, 32])
    def test_paged_kv_any_page_size(self, engine, baseline, page):
        """Any page size dividing slot_len — including one page == whole
        lane (page=32: slots are 32 long for the MIXED trace)."""
        reqs, base = baseline
        assert serve(engine, reqs, kv_page_size=page) == base

    def test_chunked_and_paged_combined(self, engine, baseline):
        reqs, base = baseline
        assert serve(engine, reqs, prefill_chunk=3, kv_page_size=4) == base
        assert serve(engine, reqs, prefill_chunk=5, kv_page_size=8,
                     mode="wave") == base

    def test_prefill_budget_does_not_change_tokens(self, engine, baseline):
        reqs, base = baseline
        assert serve(engine, reqs, prefill_chunk=2,
                     prefill_budget=16) == base

    def test_overcommitted_pool_defers_but_matches(self, engine, baseline):
        """A pool too small to back every slot admits fewer requests at a
        time (reservation gating) but generates identical tokens."""
        reqs, base = baseline
        # slots need up to 8 pages of 4; 9 usable pages < 2 slots x 8
        assert serve(engine, reqs, kv_page_size=4, kv_pages=10) == base

    @pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-v2-236b"])
    def test_windowed_and_mla_archs(self, arch):
        """Rolling-window (gemma2 local/global) and MLA latent caches:
        windowed leaves stay per-slot lanes, latent leaves page."""
        engine = make_engine(arch)
        rng = np.random.default_rng(3)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, L), g)
                for L, g in [(20, 6), (4, 3), (11, 8)]]
        base = serve(engine, reqs)
        assert serve(engine, reqs, prefill_chunk=6, kv_page_size=8) == base

    @pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mamba2-780m"])
    def test_recurrent_arch_resumes_chunked_prefill(self, arch):
        """Recurrent blocks (RG-LRU / SSM) resume a prompt mid-cache by
        seeding their scan from the cached recurrent state: chunked
        prefill is supported and token-identical to monolithic, with no
        downgrade warning (the suite escalates stray RuntimeWarnings to
        errors, so silence is asserted by construction)."""
        engine = make_engine(arch)
        assert supports_chunked_prefill(engine.cfg)
        rng = np.random.default_rng(5)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, L), g)
                for L, g in [(9, 4), (4, 6), (13, 3)]]
        base = serve(engine, reqs)
        for chunk in (1, 4, 64):
            assert serve(engine, reqs, prefill_chunk=chunk) == base, chunk

    def test_multimodal_fallback_warns_once_with_reason(self):
        """The monolithic-prefill downgrade is never silent: the first
        Scheduler that hits it raises a RuntimeWarning naming the reason
        (supports_chunked_prefill=False — a multimodal prefix cannot
        resume a prompt mid-cache); later Schedulers of the same family
        stay quiet (warn-once) but still emit the note."""
        import warnings

        from repro.runtime import scheduler as sched_mod

        engine = make_engine("paligemma-3b")
        assert not supports_chunked_prefill(engine.cfg)
        sched_mod._FALLBACK_WARNED.clear()
        with pytest.warns(RuntimeWarning,
                          match="supports_chunked_prefill=False"):
            Scheduler(engine, prefill_chunk=4, emit=lambda s: None)
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # a second warning -> fail
            notes = []
            sched = Scheduler(engine, prefill_chunk=4, emit=notes.append)
        assert sched.prefill_chunk is None
        assert any("monolithic" in n for n in notes)


class TestPageAllocator:
    def test_free_xor_allocated(self):
        a = PageAllocator(range(1, 9))
        assert a.reserve(5)
        got = [a.alloc() for _ in range(5)]
        assert len(set(got)) == 5                      # no double allocation
        assert a.n_free + a.n_allocated == a.total
        a.release(got[:2])
        assert a.n_free + a.n_allocated == a.total
        # released pages can be handed out again, still unique vs live ones
        assert a.reserve(2)
        again = [a.alloc() for _ in range(2)]
        assert not set(again) & set(got[2:])

    def test_reservation_gates_allocation(self):
        a = PageAllocator(range(4))
        assert a.reserve(3)
        assert not a.reserve(2)                        # only 1 unreserved
        assert a.reserve(1)
        assert a.available() == 0
        with pytest.raises(AssertionError):
            PageAllocator(range(2)).alloc()            # alloc w/o reserve

    def test_double_free_caught(self):
        """Releasing an id already on the free list must raise — a silent
        double free would put the page on the free list twice and hand it
        to two slots at once."""
        a = PageAllocator(range(4))
        a.reserve(1)
        pid = a.alloc()
        a.release([pid])
        with pytest.raises(ValueError, match="double free"):
            a.release([pid])

    def test_fragmented_free_list_keeps_reservations_infallible(self):
        """Interleaved admit/retire until the free list is riddled with
        holes: reservations must still make every subsequent alloc
        infallible (the mid-decode no-OOM guarantee does not depend on
        contiguity), and free xor allocated must hold throughout."""
        rng = np.random.default_rng(0)
        a = PageAllocator(range(1, 65))
        # deterministic fragmentation: admit 16 four-page requests (ids
        # hand out in order), then retire every other one — the free
        # list is now 8 disjoint runs with allocated pages between them
        assert a.reserve(64)
        groups = [[a.alloc() for _ in range(4)] for _ in range(16)]
        held: list[list[int]] = []
        for i, grp in enumerate(groups):
            if i % 2 == 0:
                a.release(grp)
            else:
                held.append(grp)
        free = sorted(a._free)
        assert any(b - c > 1 for c, b in zip(free, free[1:])), \
            "free list unexpectedly contiguous"
        # random admit/retire churn on top, invariants at every step
        for _ in range(300):
            if held and rng.random() < 0.5:
                a.release(held.pop(int(rng.integers(0, len(held)))))
            else:
                n = int(rng.integers(1, 6))
                if a.reserve(n):
                    held.append([a.alloc() for _ in range(n)])
            assert a.n_free + a.n_allocated == a.total
        # reserve every remaining page against the fragmented list, then
        # draw them all down: none may fail, none may be handed out twice
        n = a.available()
        assert n > 0 and a.reserve(n)
        got = [a.alloc() for _ in range(n)]
        live = set(got)
        for grp in held:
            live |= set(grp)
        assert len(got) == n and len(live) == a.n_allocated
        assert a.n_free == 0 and a.reserved == 0


class TestPoolInvariants:
    def test_no_page_leaked_after_retire(self, engine, baseline):
        reqs, _ = baseline
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=4, prefill_chunk=3)
        for r in reqs:
            sched.submit(*r)
        sched.run()
        pool = sched._pool
        assert pool.allocator.n_allocated == 0         # every page returned
        assert pool.allocator.reserved == 0            # every earmark undone
        assert pool.allocator.n_free == pool.allocator.total
        assert (pool.table == 0).all()                 # rows reset to dummy

    def test_tables_disjoint_during_serving(self, engine):
        """A physical page is owned by at most one slot at every decode
        step (cache reads can never cross into another slot's pages)."""
        rng = np.random.default_rng(11)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, L), g)
                for L, g in [(9, 6), (4, 8), (13, 3), (6, 5)]]
        sched = Scheduler(engine, batch_size=2, buckets=(16,),
                          kv_page_size=4)
        for r in reqs:
            sched.submit(*r)
        seen = []

        orig_step = sched._step

        def checked_step(pool, completed):
            live = pool.table[pool.table != 0]
            assert len(live) == len(set(live.tolist())), \
                f"page owned by two slots: {pool.table}"
            assert pool.allocator.n_allocated == len(live)
            seen.append(len(live))
            orig_step(pool, completed)

        sched._step = checked_step
        done = sched.run()
        assert len(done) == len(reqs) and seen and max(seen) > 0

    def test_short_requests_use_fewer_pages(self, engine):
        """Paged memory is per-request need, not per-pool worst case: a
        short request's slot allocates only the pages its positions
        reach."""
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=4, slot_len=32)
        sched.submit(np.arange(3) % engine.cfg.vocab_size, 2)    # short
        sched.submit(np.arange(20) % engine.cfg.vocab_size, 8)   # long
        sched.run()
        m = engine.metrics
        assert m.pages_total > 0
        # worst case would be 2 slots x 8 pages; the mixed pair peaks lower
        assert m.pages_in_use <= 8 + 2

    def test_grow_pages_keeps_decode_compile(self, engine):
        """Growing the physical pool re-traces only the page gather /
        scatter; the compiled vmapped decode step is untouched."""
        rng = np.random.default_rng(2)
        sched = Scheduler(engine, batch_size=2, buckets=(16,),
                          kv_page_size=4, kv_pages=5)
        sched.submit(rng.integers(0, engine.cfg.vocab_size, 8), 6)
        out1 = sched.run()
        assert len(out1) == 1
        n0 = engine._slot_decode_jit._cache_size()
        sched._pool.grow_pages(9)
        sched.submit(rng.integers(0, engine.cfg.vocab_size, 8), 6)
        sched.submit(rng.integers(0, engine.cfg.vocab_size, 8), 6)
        out2 = sched.run()
        assert len(out2) == 2
        assert engine._slot_decode_jit._cache_size() == n0
        assert sched._pool.allocator.n_allocated == 0

    def test_grow_after_fragmentation_keeps_decode_compile(self, engine):
        """Mixed-length requests retire at different times, scrambling
        the free list; growing the pool within ``kv_page_capacity``
        afterwards is pure free-list bookkeeping — no decode recompile —
        and serving through the fragmented, grown pool still completes
        with the reservation guarantee intact."""
        rng = np.random.default_rng(4)
        sched = Scheduler(engine, batch_size=2, buckets=(16,),
                          kv_page_size=4, kv_pages=9, kv_page_capacity=24)
        for length, gen in [(9, 2), (4, 7), (13, 3), (6, 5), (3, 8)]:
            sched.submit(rng.integers(0, engine.cfg.vocab_size, length),
                         gen)
        assert len(sched.run()) == 5
        pool = sched._pool
        n0 = engine._slot_decode_jit._cache_size()
        pool.grow_pages(20)            # within capacity: headroom only
        assert pool.page_capacity == 24
        assert engine._slot_decode_jit._cache_size() == n0
        for length, gen in [(8, 4), (5, 6), (12, 3)]:
            sched.submit(rng.integers(0, engine.cfg.vocab_size, length),
                         gen)
        assert len(sched.run()) == 3
        assert engine._slot_decode_jit._cache_size() == n0
        assert pool.allocator.n_allocated == 0
        assert pool.allocator.n_free == pool.allocator.total == 19

    def test_undersized_pool_raises_instead_of_spinning(self, engine):
        """A pool that cannot back even one full slot is rejected up
        front (otherwise admission would defer forever)."""
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=4, kv_pages=3, slot_len=32)
        sched.submit(np.arange(20) % engine.cfg.vocab_size, 8)
        with pytest.raises(ValueError, match="cannot back"):
            sched.run()


class TestMetrics:
    def test_chunk_and_page_counters(self, engine):
        engine.metrics = type(engine.metrics)()
        rng = np.random.default_rng(13)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, 10), 4)
                for _ in range(3)]
        serve(engine, reqs, prefill_chunk=4, kv_page_size=4)
        m = engine.metrics
        # 10-token prompts in 4-token chunks -> 3 chunks each
        assert m.prefill_chunks == 9
        assert m.prefill_chunk_tokens == 30
        assert m.pages_total > 0
        assert 0.0 < m.page_occupancy() <= 1.0
        assert "chunks" in m.stats_line() and "pages" in m.stats_line()
