"""Property tests for the attention substrate: the chunked/flash path must
equal a naive full-softmax reference under every mask regime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.models.attention import decode_attention, flash_attention


def naive_attention(q, k, v, *, causal, window, prefix_len, softcap_val=0.0):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qs = q.astype(jnp.float32).reshape(b, sq, kh, g, d) * d ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qs, k.astype(jnp.float32))
    if softcap_val:
        s = jnp.tanh(s / softcap_val) * softcap_val
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = (kpos <= qpos) if causal else jnp.ones_like(qpos * kpos, bool)
    if window:
        ok &= kpos > qpos - window
    if prefix_len:
        ok |= kpos < prefix_len
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    # (b, kh, g, sq, dv) -> (b, sq, kh, g, dv) -> (b, sq, h, dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, v.shape[-1])


def _qkv(seed, b, s, h, kh, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, h, d)) * 0.3,
            jax.random.normal(ks[1], (b, s, kh, d)) * 0.3,
            jax.random.normal(ks[2], (b, s, kh, d)) * 0.3)


class TestFlashEqualsNaive:
    @pytest.mark.parametrize("causal,window,prefix", [
        (True, 0, 0), (True, 8, 0), (False, 0, 0), (True, 0, 5),
        (True, 16, 3),
    ])
    def test_mask_regimes(self, causal, window, prefix):
        q, k, v = _qkv(0, 2, 32, 4, 2, 16)
        got = flash_attention(q, k, v, causal=causal, window=window,
                              prefix_len=prefix, q_chunk=8)
        want = naive_attention(q, k, v, causal=causal, window=window,
                               prefix_len=prefix)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_softcap(self):
        q, k, v = _qkv(1, 1, 16, 2, 2, 8)
        got = flash_attention(q, k, v, attn_softcap=5.0, q_chunk=4)
        want = naive_attention(q, k, v, causal=True, window=0,
                               prefix_len=0, softcap_val=5.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    @given(st.integers(0, 1000), st.sampled_from([1, 2, 3]),
           st.sampled_from([8, 12, 24]), st.sampled_from([4, 8]))
    @settings(max_examples=10, deadline=None)
    def test_chunking_invariance(self, seed, b, s, q_chunk):
        """The q-chunk size must never change the result."""
        q, k, v = _qkv(seed, b, s, 4, 4, 8)
        a = flash_attention(q, k, v, q_chunk=q_chunk)
        full = flash_attention(q, k, v, q_chunk=s)
        np.testing.assert_allclose(np.asarray(a), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)

    def test_causality_property(self):
        """Perturbing a future token never changes past outputs."""
        q, k, v = _qkv(7, 1, 16, 2, 2, 8)
        out1 = flash_attention(q, k, v)
        k2 = k.at[:, -1].add(10.0)
        v2 = v.at[:, -1].add(10.0)
        out2 = flash_attention(q, k2, v2)
        np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                                   np.asarray(out2[:, :-1]),
                                   rtol=1e-4, atol=1e-4)


class TestDecodeAttention:
    def test_matches_flash_last_row(self):
        q, k, v = _qkv(3, 2, 24, 4, 2, 16)
        full = flash_attention(q, k, v)
        got = decode_attention(q[:, -1:], k, v, jnp.int32(23))
        np.testing.assert_allclose(np.asarray(got[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=2e-3, atol=2e-3)

    def test_window_mask(self):
        q, k, v = _qkv(4, 1, 24, 2, 2, 8)
        want = naive_attention(q, k, v, causal=True, window=6,
                               prefix_len=0)[:, -1]
        got = decode_attention(q[:, -1:], k, v, jnp.int32(23), window=6)
        np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


class TestMoERegroup:
    def test_decode_regroup_matches_per_row(self):
        """Regrouped decode dispatch (s=1, b=32) must equal the ungrouped
        path: routing is per-token, so grouping is semantically transparent
        when capacity admits all tokens."""
        from repro.configs.base import get_config
        from repro.models import moe as moe_mod
        cfg = get_config("mixtral-8x22b").scaled(
            d_model=32, moe_d_ff=64, d_ff=64, num_experts=4, top_k=2,
            capacity_factor=8.0, dtype="float32")
        p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 1, 32)) * 0.3
        out_grouped, _ = moe_mod.moe_apply(p, x, cfg)       # s=1 -> regroup
        outs = [moe_mod.moe_apply(p, x[i:i + 1].reshape(1, 1, 32), cfg)[0]
                for i in range(4)]
        np.testing.assert_allclose(np.asarray(out_grouped[:4]),
                                   np.asarray(jnp.concatenate(outs, 0)),
                                   rtol=1e-4, atol=1e-4)


class TestFusedKernelCodes:
    @pytest.mark.parametrize("codes", [8, 16, 32])
    def test_codes_parameter(self, codes, rng):
        import jax.numpy as jnp
        from repro.core import compression
        from repro.kernels import ops, ref
        x = rng.standard_normal((9, 288 * 2)).astype(np.float32)
        wb = rng.integers(0, 2, size=(50, 288 * 2), dtype=np.uint8)
        words, tabs, meta = ops.prepare_compressed_gemm(
            wb, cluster=False, codes=codes)
        out = ops.compressed_binary_matmul(
            jnp.asarray(x), words, tabs, k_true=576, n_true=50, codes=codes)
        exp = ref.binary_matmul(jnp.asarray(x),
                                jnp.asarray(wb.astype(np.float32) * 2 - 1))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
