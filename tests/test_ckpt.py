"""Checkpoint tests: roundtrip, atomicity, resume, compressed snapshots,
elastic re-mesh restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh


def tree(rng):
    return {
        "params": {"scan": {"w": jnp.asarray(
            rng.standard_normal((4, 8, 16)).astype(np.float32))},
            "embed": jnp.asarray(rng.standard_normal((32, 16))
                                 .astype(np.float32))},
        "opt": {"step": jnp.asarray(7, jnp.int32),
                "mu": {"x": jnp.zeros((3,), jnp.float32)}},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, rng):
        t = tree(rng)
        ckpt.save(t, str(tmp_path), step=10)
        restored, step = ckpt.restore(str(tmp_path), t)
        assert step == 10
        for a, b in zip(jax.tree_util.tree_leaves(t),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_marker_and_multiple_steps(self, tmp_path, rng):
        t = tree(rng)
        ckpt.save(t, str(tmp_path), step=10)
        ckpt.save(t, str(tmp_path), step=20)
        assert ckpt.latest_step(str(tmp_path)) == 20
        _, step = ckpt.restore(str(tmp_path), t)
        assert step == 20
        _, step = ckpt.restore(str(tmp_path), t, step=10)
        assert step == 10

    def test_async_save(self, tmp_path, rng):
        t = tree(rng)
        th = ckpt.save(t, str(tmp_path), step=5, async_=True)
        th.join()
        assert ckpt.latest_step(str(tmp_path)) == 5

    def test_torn_write_invisible(self, tmp_path, rng):
        """A .tmp dir (simulated crash mid-write) is never picked up."""
        t = tree(rng)
        ckpt.save(t, str(tmp_path), step=1)
        os.makedirs(str(tmp_path / "step_2.tmp"))
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_restore_with_shardings(self, tmp_path, rng):
        t = tree(rng)
        ckpt.save(t, str(tmp_path), step=3)
        mesh = make_host_mesh()
        sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        shardings = jax.tree_util.tree_map(
            lambda x: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), t)
        restored, _ = ckpt.restore(str(tmp_path), sds, shardings=shardings)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["embed"]),
            np.asarray(t["params"]["embed"]))

    def test_compressed_binary_checkpoint(self, tmp_path, rng):
        """conv w3 leaves stored Huffman-compressed; restore reproduces
        sign * per-channel scale exactly (inference snapshot semantics)."""
        w3 = rng.standard_normal((8, 32, 3, 3)).astype(np.float32)
        t = {"blocks": [{"w3": jnp.asarray(w3)}]}
        ckpt.save(t, str(tmp_path), step=1, compress_binary=True)
        restored, _ = ckpt.restore(str(tmp_path), t)
        rec = np.asarray(restored["blocks"][0]["w3"])
        scale = np.abs(w3).mean(axis=(1, 2, 3), keepdims=True)
        expect = np.where(w3 >= 0, 1.0, -1.0) * scale
        np.testing.assert_allclose(rec, expect, rtol=1e-6)
        # and it actually saved fewer bytes than raw f32
        blob = os.path.getsize(
            os.path.join(str(tmp_path), "step_1", "host0.npz"))
        assert blob < w3.nbytes


class TestElasticRemesh:
    def test_restore_onto_new_mesh(self, tmp_path, rng):
        from repro.dist.fault import remesh
        t = {"w": jnp.asarray(rng.standard_normal((8, 16))
                              .astype(np.float32))}
        ckpt.save(t, str(tmp_path), step=2)
        new_mesh = make_host_mesh()      # "surviving" single-host mesh

        def shardings_fn(like, mesh):
            return jax.tree_util.tree_map(
                lambda x: jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()), like)

        restored, step = remesh(str(tmp_path), t, new_mesh, shardings_fn)
        assert step == 2
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(t["w"]))
