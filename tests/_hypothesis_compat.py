"""Optional-hypothesis shim: property tests degrade to skips, the rest of
the module still collects and runs when hypothesis isn't installed.

When hypothesis *is* installed, two profiles are registered and selected
via the ``HYPOTHESIS_PROFILE`` env var (the CI fuzz job exports it):

  * ``ci``   — derandomized (fixed seed, reproducible failures) with the
    default example budget; the job's deterministic first pass;
  * ``fuzz`` — short randomized pass layered on top, so every CI run
    explores a few fresh traces without flaking the deterministic gate.
"""

import os

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True

    settings.register_profile("ci", derandomize=True, deadline=None)
    settings.register_profile("fuzz", derandomize=False, deadline=None,
                              max_examples=25)
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:                                      # pragma: no cover
        settings.load_profile(_profile)
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
