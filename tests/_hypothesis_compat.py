"""Optional-hypothesis shim: property tests degrade to skips, the rest of
the module still collects and runs when hypothesis isn't installed."""

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
