"""Speculative decoding on the ragged mixed-step substrate.

Three layers of coverage:

* drafter properties — the n-gram proposer is deterministic, respects
  per-slot limits, and never invents context (empty history -> nothing);
* accept/reject math — the scheduler's greedy verification against a
  plain python reference over the same logits;
* the correctness oracle — greedy speculative decoding must be
  **token-identical** to plain decoding across architectures (dense GQA,
  rolling-window, MLA, recurrent), both attention backends, both KV
  codecs, and prefix sharing, including rollbacks that cross page
  boundaries and land on copy-on-write shared pages.
"""

import numpy as np
import pytest

from repro.models.api import supports_speculation
from repro.runtime import Scheduler
from repro.runtime.drafter import DraftModelDrafter, NGramDrafter, \
    make_drafter
from tests.harness import make_engine, mixed_requests, \
    run_trace as serve


@pytest.fixture(scope="module")
def engine():
    return make_engine()


def repetitive_requests(engine, n=4, decode=24, seed=3):
    """Prompts ending in a repeated pattern + long decode budgets: the
    reduced models' argmax chains collapse into short cycles, which is
    where n-gram drafting accepts — so these traces exercise the accept
    *and* the reject/rollback paths in the same run."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        pat = rng.integers(0, engine.cfg.vocab_size, 3)
        reqs.append((np.tile(pat, 4), decode))
    return reqs


# -- drafter properties ------------------------------------------------------
class TestNGramDrafter:
    def test_empty_history_proposes_nothing(self):
        d = NGramDrafter()
        assert list(d.propose([np.zeros(0, np.int64)], 4)[0]) == []

    def test_no_earlier_occurrence_proposes_nothing(self):
        d = NGramDrafter()
        assert list(d.propose([np.arange(10)], 4)[0]) == []

    def test_repeated_run_proposes_full_k(self):
        d = NGramDrafter()
        hist = np.asarray([3, 1, 7, 7, 7, 7, 7, 7, 7, 7])
        out = d.propose([hist], 4)[0]
        assert list(out) == [7, 7, 7, 7]

    def test_periodic_history_proposes_continuation(self):
        d = NGramDrafter()
        hist = np.asarray([5, 8, 2, 5, 8, 2, 5, 8])
        out = d.propose([hist], 3)[0]
        assert list(out)[:1] == [2]

    def test_deterministic(self):
        d = NGramDrafter()
        rng = np.random.default_rng(0)
        hists = [rng.integers(0, 16, 40) for _ in range(8)]
        a = d.propose(hists, 4)
        b = d.propose(hists, 4)
        for x, y in zip(a, b):
            assert list(x) == list(y)

    def test_limits_cap_each_slot(self):
        d = NGramDrafter()
        hist = np.asarray([7] * 12)
        for lim in (0, 1, 2, 4):
            out = d.propose([hist], 4, limits=[lim])[0]
            assert len(out) <= lim

    def test_never_proposes_more_than_k(self):
        d = NGramDrafter()
        rng = np.random.default_rng(1)
        hists = [rng.integers(0, 4, 64) for _ in range(16)]
        for k in (1, 2, 5):
            for out in d.propose(hists, k):
                assert len(out) <= k

    def test_make_drafter_resolution(self, engine):
        assert make_drafter("off") is None
        assert make_drafter(None) is None
        assert isinstance(make_drafter("ngram"), NGramDrafter)
        assert isinstance(make_drafter("draft", engine), DraftModelDrafter)
        with pytest.raises(ValueError, match="unknown speculate"):
            make_drafter("medusa")
        with pytest.raises(ValueError, match="needs an engine"):
            make_drafter("draft")


# -- accept/reject math ------------------------------------------------------
class TestAcceptance:
    def test_accept_is_longest_matching_prefix(self):
        """The scheduler's acceptance loop against a python reference:
        accept a = longest prefix of drafts matching the model's argmax
        chain; the emitted block is g[0..a] (a drafts + the bonus
        token), never more, never past the first mismatch."""
        g = [10, 11, 12, 13, 14]               # model argmax per row
        for draft, want_a in [([], 0),
                              ([10], 1),
                              ([10, 11], 2),
                              ([10, 11, 12, 13], 4),
                              ([99], 0),
                              ([10, 99], 1),
                              ([10, 11, 99, 13], 2),
                              ([99, 11, 12], 0)]:
            a = 0
            while a < len(draft) and draft[a] == g[a]:
                a += 1
            assert a == want_a, (draft, a, want_a)
            emitted = g[:a + 1]
            assert len(emitted) == a + 1
            # every emitted token is the model's own argmax: greedy
            # verification can never emit a draft the model disagreed on
            assert all(t == g[i] for i, t in enumerate(emitted))

    def test_spec_emits_model_tokens_not_drafts(self, engine):
        """End-to-end: force a drafter that always proposes garbage —
        output must still equal plain decoding (every garbage draft is
        rejected; only model argmax tokens are ever emitted)."""
        import repro.runtime.drafter as dr

        class GarbageDrafter(dr.Drafter):
            def propose(self, histories, k, limits=None):
                return [dr._clamp(
                    np.full(k, (engine.cfg.vocab_size - 1), np.int64),
                    k, None if limits is None else limits[i])
                    for i, _ in enumerate(histories)]

        reqs = mixed_requests(engine)
        base = serve(engine, reqs)
        orig = dr.make_drafter
        dr.make_drafter = lambda spec, eng=None: GarbageDrafter()
        try:
            out = serve(engine, reqs, speculate="ngram")
        finally:
            dr.make_drafter = orig
        assert out == base
        # nothing can be accepted: vocab-1 is (vanishingly unlikely to
        # be) the argmax everywhere, so acceptance stays ~0 while the
        # tokens stay exact
        assert engine.metrics.spec_rejected_tokens > 0


# -- the correctness oracle --------------------------------------------------
class TestTokenIdentity:
    """Greedy speculative decoding == plain decoding, token for token."""

    @pytest.mark.parametrize("speculate", ["ngram", "draft"])
    def test_monolithic_lanes(self, engine, speculate):
        reqs = repetitive_requests(engine)
        base = serve(engine, reqs)
        assert serve(engine, reqs, speculate=speculate) == base

    @pytest.mark.parametrize("backend", ["gathered", "pallas_paged"])
    @pytest.mark.parametrize("codec", ["none", "cluster"])
    def test_backends_and_codecs(self, engine, backend, codec):
        reqs = repetitive_requests(engine)
        base = serve(engine, reqs, kv_page_size=4, attn_backend=backend,
                     kv_codec=codec)
        out = serve(engine, reqs, kv_page_size=4, attn_backend=backend,
                    kv_codec=codec, speculate="ngram")
        assert out == base

    @pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-v2-236b",
                                      "recurrentgemma-2b", "mamba2-780m"])
    def test_archs(self, arch):
        """Rolling-window (lane snapshot/restore on the kernel path),
        MLA latent caches (ragged masked writes), and both recurrent
        kinds (state resume carries the verify block)."""
        engine = make_engine(arch)
        assert supports_speculation(engine.cfg)
        reqs = repetitive_requests(engine)
        base = serve(engine, reqs)
        assert serve(engine, reqs, speculate="ngram") == base

    @pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-v2-236b"])
    def test_archs_paged_kernel(self, arch):
        engine = make_engine(arch)
        reqs = repetitive_requests(engine)
        kw = dict(kv_page_size=4, attn_backend="pallas_paged")
        base = serve(engine, reqs, **kw)
        assert serve(engine, reqs, speculate="ngram", **kw) == base

    def test_rollback_across_page_boundaries(self, engine):
        """draft_k > page_size: every verify block spans a page
        boundary, so rejected drafts must be rolled back across pages.
        Tiny pages + deep drafts on both backends."""
        reqs = repetitive_requests(engine, decode=16)
        for backend in ("gathered", "pallas_paged"):
            kw = dict(kv_page_size=2, attn_backend=backend)
            base = serve(engine, reqs, **kw)
            out = serve(engine, reqs, speculate="ngram", draft_k=6, **kw)
            assert out == base, backend

    def test_rollback_on_cow_shared_pages(self, engine):
        """Prefix sharing + speculation: identical prompts map shared
        pages; draft writes hit the copy-on-write barrier before any
        speculative write, so a rejected draft can never corrupt a page
        another request (or the prefix index) still reads."""
        rng = np.random.default_rng(9)
        shared = rng.integers(0, engine.cfg.vocab_size, 12)
        reqs = [(shared, 12), (shared, 12), (shared, 8)]
        kw = dict(kv_page_size=4, prefill_chunk=4, prefix_share=True)
        base = serve(engine, reqs, **kw)
        out = serve(engine, reqs, speculate="ngram", draft_k=6, **kw)
        assert out == base
        assert engine.metrics.prefix_hits > 0

    def test_prefix_share_on_kernel_backend(self, engine):
        rng = np.random.default_rng(9)
        shared = rng.integers(0, engine.cfg.vocab_size, 12)
        reqs = [(shared, 12), (shared, 12)]
        kw = dict(kv_page_size=4, prefill_chunk=4, prefix_share=True,
                  attn_backend="pallas_paged")
        base = serve(engine, reqs, **kw)
        assert serve(engine, reqs, speculate="ngram", **kw) == base

    def test_chunked_prefill_interleaved(self, engine):
        """Chunk ticks and speculative decode ticks share the mixed
        trace: drafts are clamped into the chunk width so compile
        shapes stay bounded, and tokens stay exact."""
        reqs = repetitive_requests(engine, n=5)
        for backend in ("gathered", "pallas_paged"):
            kw = dict(kv_page_size=4, prefill_chunk=3,
                      attn_backend=backend)
            base = serve(engine, reqs, **kw)
            out = serve(engine, reqs, speculate="ngram", **kw)
            assert out == base, backend

    @pytest.mark.parametrize("draft_k", [1, 3, 8])
    def test_any_draft_depth(self, engine, draft_k):
        reqs = repetitive_requests(engine, n=3)
        base = serve(engine, reqs)
        assert serve(engine, reqs, speculate="ngram",
                     draft_k=draft_k) == base


# -- wiring ------------------------------------------------------------------
class TestSchedulerWiring:
    def test_acceptance_metrics_recorded(self, engine):
        engine.metrics = type(engine.metrics)()
        reqs = repetitive_requests(engine)
        serve(engine, reqs, speculate="ngram")
        m = engine.metrics
        assert m.spec_rounds > 0
        assert m.spec_draft_tokens == \
            m.spec_accepted_tokens + m.spec_rejected_tokens
        assert 0.0 <= m.spec_acceptance_rate() <= 1.0
        assert m.spec_accepted_tokens > 0      # repetitive trace accepts
        # accepted drafts shrink steps-per-token below the 1-token/step
        # baseline of plain decoding
        assert m.decode_steps < m.slot_steps
        line = m.stats_line()
        assert "drafts accepted" in line
        prom = m.render_prom()
        assert "spec_accepted_tokens_total" in prom
        assert "spec_acceptance_rate" in prom

    def test_speculation_off_by_default(self, engine):
        sched = Scheduler(engine, batch_size=2, buckets=(32,))
        assert sched.drafter is None

    def test_bad_draft_k_rejected(self, engine):
        with pytest.raises(ValueError, match="draft_k"):
            Scheduler(engine, batch_size=2, buckets=(32,),
                      speculate="ngram", draft_k=0)

    def test_multimodal_arch_falls_back_with_note(self):
        """Speculation rides the resume-from-cache machinery; a vlm
        prompt cannot resume mid-cache, so the scheduler downgrades to
        plain decoding with a warn-once + note instead of failing."""
        from repro.runtime import scheduler as sched_mod

        engine = make_engine("paligemma-3b")
        assert not supports_speculation(engine.cfg)
        notes = []
        sched_mod._FALLBACK_WARNED.clear()
        with pytest.warns(RuntimeWarning,
                          match="supports_speculation=False"):
            sched = Scheduler(engine, batch_size=2, buckets=(32,),
                              speculate="ngram", emit=notes.append)
        assert sched.drafter is None
        assert any("speculative" in n for n in notes)

    def test_draft_model_rides_weight_store(self, engine):
        """The draft model's compressible tiles register under
        model_id='draft' in the scheduler's shared WeightStore instead
        of doubling resident raw weights."""
        drafter = DraftModelDrafter(engine)
        assert drafter.store is engine.store
        if drafter._raw is None:
            assert "draft" in drafter.store.models()
