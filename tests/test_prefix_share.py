"""Differential fuzz harness for prefix sharing with copy-on-write KV
pages.

Three layers, cheapest first:

* **allocator fuzz** — seeded multi-owner churn traces over the
  refcounted :class:`PageAllocator` against a pure-python reference
  refcount model; every step checks the conservation invariants (every
  id free xor allocated-with-refcount >= 1, external refs == allocator
  refcounts, no id on both lists) and every trace drains to empty.
* **index model fuzz** — seeded register/lookup traces over
  :class:`PrefixIndex` against a longest-common-prefix oracle built from
  the raw registered prompts: ``lookup`` must return exactly
  ``min(max_r lcp(prompt, r), len - 1)`` floored to the chunk alignment,
  and the returned nodes must spell the matched tokens page by page.
* **differential serving** — real Scheduler traces (shared, partially
  shared, mid-prefix-divergent, and disjoint prompts, plus
  retire-readmit churn) must generate byte-identical tokens with
  sharing on vs off across both attention backends and both KV codecs,
  while the accounting identity ``chunk_tokens(on) + tokens_reused ==
  chunk_tokens(off)`` pins that the reuse is real skipped prefill work
  — and copy-on-write must never leave a written page shared.

The deterministic seed grids alone cover 200+ traces (110 allocator +
96 index + the serving grid); the hypothesis drivers at the bottom
re-run the same check functions over randomized traces in CI (see
tests/_hypothesis_compat.py for the profiles).
"""

import numpy as np
import pytest

from repro.runtime import PageAllocator, PrefixIndex, Scheduler, SlotPool
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from tests.harness import assert_tokens_identical, make_engine
from tests.harness import run_trace as serve

# ---------------------------------------------------------------------------
# refcount semantics (unit)
# ---------------------------------------------------------------------------


class TestRefcounts:
    def test_alloc_share_release_lifecycle(self):
        a = PageAllocator(range(1, 5))
        assert a.reserve(1)
        pid = a.alloc()
        assert a.refcount(pid) == 1 and a.shared_pages() == 0
        assert a.share(pid) == pid
        assert a.refcount(pid) == 2 and a.shared_pages() == 1
        a.release([pid])                       # drops to 1: still allocated
        assert a.refcount(pid) == 1 and a.n_allocated == 1
        assert a.shared_pages() == 0
        a.release([pid])                       # last ref: back on free list
        assert a.refcount(pid) == 0 and a.n_allocated == 0
        assert a.n_free == a.total

    def test_share_of_unallocated_page_raises(self):
        a = PageAllocator(range(1, 5))
        with pytest.raises(ValueError, match="unallocated"):
            a.share(2)
        assert a.reserve(1)
        pid = a.alloc()
        a.release([pid])
        with pytest.raises(ValueError, match="unallocated"):
            a.share(pid)

    def test_double_free_raises(self):
        """Regression: releasing a freed id used to silently append it to
        the free list again, letting two slots own one physical page."""
        a = PageAllocator(range(1, 5))
        assert a.reserve(2)
        pid, other = a.alloc(), a.alloc()
        a.release([pid])
        n_free = a.n_free
        with pytest.raises(ValueError, match="double free"):
            a.release([pid])
        assert a.n_free == n_free             # free list not corrupted
        with pytest.raises(ValueError, match="double free"):
            a.release([99])                   # never-allocated id: same guard
        a.release([other])

    def test_share_consumes_no_free_pages_or_reservation(self):
        a = PageAllocator(range(1, 4))
        assert a.reserve(1)
        pid = a.alloc()
        free, reserved = a.n_free, a.reserved
        for _ in range(5):
            a.share(pid)
        assert (a.n_free, a.reserved) == (free, reserved)
        a.release([pid] * 6)
        assert a.n_allocated == 0


# ---------------------------------------------------------------------------
# allocator fuzz: seeded churn vs a reference refcount model
# ---------------------------------------------------------------------------

def check_allocator_churn(seed: int, steps: int = 60) -> None:
    """One churn trace: random alloc-groups / extra shares / releases,
    with the full invariant set asserted after every step and a drain
    check at the end.  ``held`` is the reference model — one entry per
    outstanding reference."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(range(1, 25))
    held: list[list[int]] = []
    for _ in range(steps):
        op = rng.random()
        if op < 0.40 and a.available() > 0:
            n = int(rng.integers(1, min(a.available(), 4) + 1))
            assert a.reserve(n)
            held.append([a.alloc() for _ in range(n)])
        elif op < 0.60 and held:
            grp = held[int(rng.integers(len(held)))]
            pid = grp[int(rng.integers(len(grp)))]
            held.append([a.share(pid)])
        elif held:
            a.release(held.pop(int(rng.integers(len(held)))))
        # -- invariants, every step --
        assert a.n_free + a.n_allocated == a.total
        refs: dict[int, int] = {}
        for grp in held:
            for pid in grp:
                refs[pid] = refs.get(pid, 0) + 1
        assert set(refs) == a._allocated, "allocated <-> referenced"
        for pid, n in refs.items():
            assert a.refcount(pid) == n, f"refcount drift on page {pid}"
        assert not set(a._free) & a._allocated, "id free AND allocated"
        assert all(a.refcount(pid) == 0 for pid in a._free)
        assert a.reserved == 0
    while held:
        a.release(held.pop())
    assert a.n_allocated == 0 and a.n_free == a.total
    assert not a._refs


class TestAllocatorFuzz:
    @pytest.mark.parametrize("seed", range(110))
    def test_churn_trace(self, seed):
        check_allocator_churn(seed)


# ---------------------------------------------------------------------------
# index model fuzz: lookup vs a longest-common-prefix oracle
# ---------------------------------------------------------------------------

def _lcp(a, b) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def check_index_model(seed: int, steps: int = 25) -> None:
    """One register/lookup trace.  Prompts come from a tiny vocabulary so
    shared, partially shared, and divergent prefixes all occur; after
    every lookup the match length and node spans are checked against the
    raw-prompt oracle, and after every registration the allocator
    invariants are re-checked."""
    rng = np.random.default_rng(seed)
    P = int(rng.choice([2, 3, 4]))
    align = int(rng.choice([1, 2, 4]))
    a = PageAllocator(range(1, 65))
    idx = PrefixIndex(a, P)
    registered: list[tuple] = []
    for _ in range(steps):
        L = int(rng.integers(1, 11))
        prompt = tuple(int(t) for t in rng.integers(0, 2, L))
        nodes, matched = idx.lookup(prompt, L - 1, align)
        # oracle: longest common prefix against any registered prompt,
        # capped below the prompt length, floored to the chunk alignment
        want = min(max((_lcp(prompt, r) for r in registered), default=0),
                   L - 1)
        want -= want % align
        assert matched == (want if want > 0 else 0), \
            f"lookup {matched} != oracle {want} for {prompt}"
        assert len(nodes) == (-(-matched // P) if matched else 0)
        for k, node in enumerate(nodes):
            span = prompt[k * P:min((k + 1) * P, matched)]
            assert node.tokens[:len(span)] == span, \
                f"node {k} covers {node.tokens}, expected prefix {span}"
            assert a.refcount(node.page) >= 1
        # simulate the slot lifecycle: map (share), alloc the rest,
        # register, retire (release every slot-held ref)
        n_pages = -(-L // P)
        n_mapped = matched // P
        row = [a.share(nodes[j].page) for j in range(n_mapped)]
        if not a.reserve(n_pages - n_mapped):
            a.release(row)
            continue                           # pool exhausted: skip admit
        row += [a.alloc() for _ in range(n_pages - n_mapped)]
        idx.register(prompt, row)
        registered.append(prompt)
        a.release(row)
        # -- invariants: the index's own refs keep exactly its nodes --
        assert a.n_free + a.n_allocated == a.total
        pages = [n.page for n in idx._nodes()]
        assert len(set(pages)) == len(pages), "two nodes share a page"
        assert all(a.refcount(p) >= 1 for p in pages)
        assert a.n_allocated == len(pages)
        assert idx.tokens_cached == sum(len(n.tokens)
                                        for n in idx._nodes())
    # eviction drains everything once nothing is mapped
    dropped = idx.evict_until(a.total + 1)
    assert dropped + idx.n_nodes >= 0 and idx.clear() >= 0
    assert a.n_allocated == 0 and a.n_free == a.total and not a._refs


class TestPrefixIndexModel:
    @pytest.mark.parametrize("seed", range(96))
    def test_register_lookup_trace(self, seed):
        check_index_model(seed)

    def test_register_dedupes_identical_spans(self):
        a = PageAllocator(range(1, 9))
        idx = PrefixIndex(a, 2)
        assert a.reserve(4)
        row1 = [a.alloc(), a.alloc()]
        idx.register((1, 2, 3, 4), row1)
        a.release(row1)
        assert idx.n_nodes == 2 and a.n_allocated == 2
        row2 = [a.share(next(iter(idx._root.children.values())).page),
                a.alloc()]
        idx.register((1, 2, 3, 4), row2)      # same spans: no new nodes
        a.release(row2)
        assert idx.n_nodes == 2 and a.n_allocated == 2

    def test_eviction_only_drops_childless_nodes(self):
        a = PageAllocator(range(1, 9))
        idx = PrefixIndex(a, 2)
        assert a.reserve(3)
        row = [a.alloc() for _ in range(3)]
        idx.register((1, 2, 3, 4, 5), row)    # 2 full pages + partial
        a.release(row)
        assert idx.n_nodes == 3
        idx.evict_until(a.n_free + 1)         # free exactly one more page
        assert idx.n_nodes == 2               # a leaf went, parents stayed
        remaining = list(idx._nodes())
        assert all(len(n.tokens) == 2 for n in remaining) \
            or any(n.children for n in remaining)
        idx.evict_until(a.total + 1)
        assert idx.n_nodes == 0 and a.n_allocated == 0

    def test_evicted_but_mapped_page_degrades_to_private(self):
        """Evicting a node releases only the index's reference: a slot
        still mapping the page keeps it allocated at refcount 1 (plain
        private ownership — copy-on-write no longer triggers on it)."""
        a = PageAllocator(range(1, 5))
        idx = PrefixIndex(a, 2)
        assert a.reserve(1)
        row = [a.alloc()]
        idx.register((7, 8), row)
        a.release(row)                        # retire: index ref remains
        pid = next(idx._nodes()).page
        slot_ref = a.share(pid)               # a later hit maps the page
        assert a.refcount(pid) == 2
        assert idx.evict_until(a.total) >= 1
        assert idx.n_nodes == 0
        assert a.refcount(pid) == 1 and a.n_allocated == 1
        a.release([slot_ref])
        assert a.n_allocated == 0 and a.n_free == a.total


# ---------------------------------------------------------------------------
# differential serving: sharing on == sharing off, token for token
# ---------------------------------------------------------------------------

def prefix_requests(engine, seed=0):
    """Shared, partially shared, divergent, and disjoint prompts: four
    requests extend one 16-token prefix, one diverges mid-prefix, two
    are unrelated."""
    rng = np.random.default_rng(seed)
    V = engine.cfg.vocab_size
    common = rng.integers(0, V, 16)
    reqs = [(np.concatenate([common, rng.integers(0, V, int(t))]), g)
            for t, g in [(3, 5), (5, 4), (2, 6), (6, 3)]]
    div = common.copy()
    div[9] = (div[9] + 1) % V
    reqs.append((np.concatenate([div, rng.integers(0, V, 3)]), 4))
    reqs.append((rng.integers(0, V, 7), 5))
    reqs.append((rng.integers(0, V, 21), 3))
    return reqs


@pytest.fixture(scope="module")
def engine():
    return make_engine()


GRID = [
    ("gathered", "none", 4),
    ("gathered", "none", 8),
    ("gathered", "cluster", 8),
    pytest.param("pallas_paged", "none", 8, marks=pytest.mark.pallas),
    pytest.param("pallas_paged", "cluster", 4, marks=pytest.mark.pallas),
]


class TestDifferentialServing:
    @pytest.mark.parametrize("backend,codec,page", GRID)
    def test_tokens_identical_and_work_conserved(self, engine, backend,
                                                 codec, page):
        """Sharing on vs off: byte-identical tokens, and every reused
        token is a prefill chunk token the off run had to compute —
        ``chunk_tokens(on) + tokens_reused == chunk_tokens(off)`` (so a
        fully cached prefix costs exactly zero prefill work)."""
        reqs = prefix_requests(engine)
        kw = dict(kv_page_size=page, prefill_chunk=4, attn_backend=backend,
                  kv_codec=codec)
        engine.metrics = type(engine.metrics)()
        off = serve(engine, reqs, **kw)
        chunk_tokens_off = engine.metrics.prefill_chunk_tokens
        engine.metrics = type(engine.metrics)()
        on = serve(engine, reqs, prefix_share=True, **kw)
        m = engine.metrics
        assert_tokens_identical(on, off, f"{backend}/{codec}/page{page}")
        assert m.prefix_hits > 0 and m.prefix_tokens_reused > 0
        assert m.prefill_chunk_tokens + m.prefix_tokens_reused \
            == chunk_tokens_off
        assert m.prefix_tokens_reused % 4 == 0    # chunk-aligned matches

    def test_retire_readmit_churn(self, engine):
        """The same prompts resubmitted to a warm scheduler: every
        request now extends a registered prefix, tokens stay identical
        to the cold pass, and reuse strictly grows."""
        engine.metrics = type(engine.metrics)()
        reqs = prefix_requests(engine)
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=8, prefill_chunk=4,
                          prefix_share=True)
        rids = {sched.submit(*r).rid: i for i, r in enumerate(reqs)}
        cold = {rids[r.rid]: tuple(r.generated) for r in sched.run()}
        reused_cold = engine.metrics.prefix_tokens_reused
        rids = {sched.submit(*r).rid: i for i, r in enumerate(reqs)}
        warm = {rids[r.rid]: tuple(r.generated) for r in sched.run()}
        assert_tokens_identical(warm, cold, "readmit")
        m = engine.metrics
        assert m.prefix_tokens_reused > reused_cold
        # warm pass: every sharing-eligible prompt (len > chunk after the
        # limit cap) hits; 6 of the 7 prompts qualify
        assert m.prefix_hits >= 6

    def test_drain_leaves_only_index_references(self, engine):
        """After the queue drains, the only live pages are the index's
        (one reference each); ``clear`` releases them all and the pool
        returns to empty — no leak in either direction."""
        reqs = prefix_requests(engine)
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=8, prefill_chunk=4,
                          prefix_share=True)
        for r in reqs:
            sched.submit(*r)
        assert len(sched.run()) == len(reqs)
        pool = sched._pool
        a = pool.allocator
        assert a.reserved == 0
        assert a.n_allocated == pool.prefix.n_nodes > 0
        for node in pool.prefix._nodes():
            assert a.refcount(node.page) == 1
        assert pool.prefix.clear() > 0
        assert a.n_allocated == 0 and a.n_free == a.total
        assert (pool.table == 0).all()

    def test_cow_never_leaves_a_written_page_shared(self, engine,
                                                    monkeypatch):
        """The core copy-on-write safety property, asserted at every
        barrier call during a real serving trace: after
        ``_prepare_write(slot, lo, hi)`` returns, no page backing
        positions [lo, hi] of that slot is shared (refcount must be 1 —
        the write cannot alias another owner's bytes)."""
        orig = SlotPool._prepare_write
        barriers = []

        def checked(pool, slot, lo_pos, hi_pos):
            orig(pool, slot, lo_pos, hi_pos)
            if pool.prefix is None:
                return
            row = pool.table[slot.index]
            P = pool.page_size
            for j in range(lo_pos // P, hi_pos // P + 1):
                pid = int(row[j])
                if pid:
                    assert pool.allocator.refcount(pid) == 1, \
                        f"page {pid} still shared after COW barrier"
                    barriers.append(pid)

        monkeypatch.setattr(SlotPool, "_prepare_write", checked)
        reqs = prefix_requests(engine)
        engine.metrics = type(engine.metrics)()
        base = serve(engine, reqs, kv_page_size=8, prefill_chunk=4)
        got = serve(engine, reqs, kv_page_size=8, prefill_chunk=4,
                    prefix_share=True)
        assert_tokens_identical(got, base, "cow-instrumented")
        assert barriers and engine.metrics.prefix_cow_copies > 0

    def test_metrics_and_stats_line(self, engine):
        engine.metrics = type(engine.metrics)()
        reqs = prefix_requests(engine)
        serve(engine, reqs, kv_page_size=8, prefill_chunk=4,
              prefix_share=True)
        m = engine.metrics
        assert m.prefix_hits > 0
        assert m.prefill_chunks_avoided > 0
        assert m.shared_page_steps > 0
        assert "prefix" in m.stats_line() and "toks reused" in m.stats_line()
        prom = m.registry().render()
        assert "repro_prefix_tokens_reused_total" in prom
        assert "repro_shared_pages" in prom

    def test_sharing_off_by_default(self, engine):
        engine.metrics = type(engine.metrics)()
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          kv_page_size=8, prefill_chunk=4)
        sched.submit(np.arange(9) % engine.cfg.vocab_size, 2)
        sched.run()
        assert sched._pool.prefix is None
        assert engine.metrics.prefix_hits == 0


class TestGating:
    def test_requires_page_size(self, engine):
        with pytest.raises(ValueError, match="kv_page_size"):
            Scheduler(engine, prefix_share=True, prefill_chunk=4)

    def test_requires_prefill_chunk(self, engine):
        with pytest.raises(ValueError, match="prefill_chunk"):
            Scheduler(engine, prefix_share=True, kv_page_size=8)

    def test_windowed_arch_downgrades_with_note(self):
        """gemma2's rolling-window leaves stay per-slot lanes, so a
        shared page cannot carry the whole prefix state: prefix_share
        downgrades (warn-once + note) and serving stays correct."""
        from repro.runtime import scheduler as sched_mod

        engine = make_engine("gemma2-2b")
        sched_mod._FALLBACK_WARNED.clear()
        notes = []
        with pytest.warns(RuntimeWarning,
                          match="supports_prefix_share=False"):
            sched = Scheduler(engine, kv_page_size=8, prefill_chunk=4,
                              prefix_share=True, emit=notes.append)
        assert not sched.prefix_share
        assert any("shared" in n for n in notes)
        rng = np.random.default_rng(1)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, 11), 3)]
        base = serve(engine, reqs)
        got = serve(engine, reqs, kv_page_size=8, prefill_chunk=4,
                    prefix_share=True)
        assert got == base


# ---------------------------------------------------------------------------
# hypothesis drivers (randomized traces on top of the seed grids; CI)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    seed_st = st.integers(min_value=0, max_value=2 ** 32 - 1)

    @settings(max_examples=60, deadline=None)
    @given(seed=seed_st, steps=st.integers(10, 120))
    def test_allocator_churn_property(seed, steps):
        check_allocator_churn(seed, steps)

    @settings(max_examples=40, deadline=None)
    @given(seed=seed_st, steps=st.integers(5, 40))
    def test_index_model_property(seed, steps):
        check_index_model(seed, steps)
