"""Property + kernel tests for the KV page codec (``kernels.kv_codec``).

Two layers, in the style of tests/test_property_core.py:

* deterministic seed grid (always runs) + hypothesis drivers (CI) over
  the codec invariants the serving stack relies on:
  - roundtrip error is elementwise-bounded by ``error_bound(scale)``
    (= scale / 254, half a quantization step);
  - encode∘decode is idempotent — re-encoding a decoded page recovers
    the exact codes and scales (the gathered backend re-encodes whole
    views every scatter, so drift would compound);
  - the compressed page (int8 codes + one f32 scale per token) is never
    larger than the fp32 page it replaces;
  - all-zero pages (the page-0 dummy sink) encode to code 0 / scale 0
    and decode back to exactly zero;
  - the at-rest Huffman archive (``archive_pages``/``restore_pages``)
    is lossless and its report ratios are sane.

* pallas-marked kernel tests (the CI kernels-interpret job runs these):
  the in-kernel codebook dequant path of ``kernels.paged_attention``
  must be bit-identical to running the fp kernel on an up-front-decoded
  pool — for plain GQA and for the MLA second score operand — and the
  poison-resistant dummy-sink guarantee must survive the codec.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kv_codec
from repro.kernels.paged_attention import paged_decode_attention
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from tests.harness import (MIXED, assert_tokens_identical, make_engine,
                           mixed_requests)
from tests.harness import run_trace as serve
from tests.test_paged_attention import random_paged_cache

SEED_GRID = [0, 1, 2, 3, 17, 255]

# (shape, feature axes) grid covering the layouts the SlotPool encodes:
# attention K/V pages (page, KH, HD), MLA latent rows (page, r), and
# scan-stacked pools with leading repeat dims
SHAPES = [
    ((6, 4, 2, 8), (-2, -1)),     # (pages, page, KH, HD)
    ((3, 5, 16), (-1,)),          # (pages, page, r) MLA latent
    ((2, 4, 3, 2, 8), (-2, -1)),  # scan-stacked (R, pages, page, KH, HD)
    ((7, 1), (-1,)),              # degenerate single-feature tokens
]


def random_values(seed: int, shape, magnitude: float = 1.0) -> np.ndarray:
    """Normal values with a few exact zeros and one huge outlier mixed
    in, scaled by ``magnitude`` (exercises tiny and huge dynamic
    ranges)."""
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(shape).astype(np.float32) * magnitude
    flat = v.reshape(-1)
    if flat.size > 3:
        flat[:: max(flat.size // 3, 1)] = 0.0
        flat[1] = 100.0 * magnitude
    return v


def expand_scale(scale, axes, ndim):
    """Re-insert the squeezed feature axes for broadcasting."""
    for ax in sorted(tuple(a % ndim for a in axes)):
        scale = np.expand_dims(scale, ax)
    return scale


# ---------------------------------------------------------------------------
# check functions (shared by deterministic grid + hypothesis drivers)
# ---------------------------------------------------------------------------

def check_roundtrip_bound_and_idempotence(values, axes) -> None:
    codes, scale = kv_codec.encode(values, axes)
    assert codes.dtype == jnp.int8 and codes.shape == values.shape
    sc = expand_scale(np.asarray(scale), axes, values.ndim)
    recon = np.asarray(kv_codec.decode(codes, sc))
    bound = np.asarray(kv_codec.error_bound(sc))
    err = np.abs(recon - np.asarray(values, np.float32))
    assert (err <= bound + 1e-7 * np.abs(sc)).all(), \
        f"max err {err.max()} exceeds bound {bound.max()}"
    # idempotence: the amax element maps to ±MAX_CODE exactly, so
    # re-encoding the reconstruction recovers identical codes and scales
    codes2, scale2 = kv_codec.encode(recon, axes)
    np.testing.assert_array_equal(np.asarray(codes2), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(scale2), np.asarray(scale))


def check_compressed_not_larger(values, axes) -> None:
    """int8 codes + one f32 scale per token never exceed the fp32 page
    whenever the token's feature block has >= 2 elements (every real KV
    layout; a single-feature token would pay 5 bytes for 4 — the byte
    accounting in SlotPool counts that case honestly too)."""
    codes, scale = kv_codec.encode(values, axes)
    if values.size // max(scale.size, 1) < 2:
        return
    fp_bytes = values.size * 4                      # fp32 page at rest
    packed = codes.size * codes.dtype.itemsize + scale.size * 4
    assert packed <= fp_bytes, (packed, fp_bytes)


def check_zero_page_stays_zero(shape, axes) -> None:
    zero = np.zeros(shape, np.float32)
    codes, scale = kv_codec.encode(zero, axes)
    assert not np.asarray(codes).any()
    assert not np.asarray(scale).any()
    sc = expand_scale(np.asarray(scale), axes, zero.ndim)
    assert not np.asarray(kv_codec.decode(codes, sc)).any()
    assert not np.asarray(kv_codec.error_bound(sc)).any()


def check_archive_roundtrip(codes: np.ndarray) -> None:
    words, nbits, assign = kv_codec.archive_pages(codes)
    assert words.dtype == np.uint32 and words.size == -(-nbits // 32)
    out = kv_codec.restore_pages(words, nbits, assign, codes.shape)
    np.testing.assert_array_equal(out, codes)


# ---------------------------------------------------------------------------
# deterministic grid (runs with or without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEED_GRID)
@pytest.mark.parametrize("shape,axes", SHAPES)
def test_roundtrip_grid(seed, shape, axes):
    rng = np.random.default_rng(seed + 4000)
    mag = float(10.0 ** rng.integers(-6, 7))
    v = random_values(seed, shape, mag)
    check_roundtrip_bound_and_idempotence(v, axes)
    check_compressed_not_larger(v, axes)


@pytest.mark.parametrize("shape,axes", SHAPES)
def test_zero_page_grid(shape, axes):
    check_zero_page_stays_zero(shape, axes)


@pytest.mark.parametrize("seed", SEED_GRID)
def test_archive_roundtrip_grid(seed):
    rng = np.random.default_rng(seed + 5000)
    shape = (int(rng.integers(1, 5)), int(rng.integers(1, 33)), 8)
    codes = rng.integers(-127, 128, shape).astype(np.int8)
    check_archive_roundtrip(codes)


def test_huffman_report_skewed_codes_compress():
    """KV codes concentrated around zero (the serving distribution) get
    an at-rest Huffman ratio > 1 vs the 8-bit resident pool; clustering
    reports at least as short an average code."""
    rng = np.random.default_rng(0)
    codes = np.clip(rng.normal(0.0, 6.0, 4096).round(), -127, 127) \
        .astype(np.int8)
    rep = kv_codec.huffman_report(codes)
    assert rep["symbols"] == 4096
    assert rep["ratio"] > 1.0
    assert rep["clustered_avg_bits"] <= rep["avg_bits"] + 1e-9
    check_archive_roundtrip(codes.reshape(64, 64))


# ---------------------------------------------------------------------------
# hypothesis drivers (skipped cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    seed_st = st.integers(min_value=0, max_value=2 ** 32 - 1)

    @settings(max_examples=60, deadline=None)
    @given(seed=seed_st, pages=st.integers(1, 6), page=st.integers(1, 8),
           kh=st.integers(1, 3), hd=st.integers(1, 16),
           mag_exp=st.integers(-6, 6))
    def test_roundtrip_property(seed, pages, page, kh, hd, mag_exp):
        v = random_values(seed, (pages, page, kh, hd), 10.0 ** mag_exp)
        check_roundtrip_bound_and_idempotence(v, (-2, -1))
        check_compressed_not_larger(v, (-2, -1))

    @settings(max_examples=40, deadline=None)
    @given(seed=seed_st, n=st.integers(1, 256))
    def test_archive_property(seed, n):
        rng = np.random.default_rng(seed)
        check_archive_roundtrip(
            rng.integers(-127, 128, (n,)).astype(np.int8))


# ---------------------------------------------------------------------------
# in-kernel dequant path (CI kernels-interpret job runs these)
# ---------------------------------------------------------------------------

def encode_pool(pages: np.ndarray):
    """Pool (n_pages, page, *feat) -> (int8 codes, (n_pages, page)
    scales, decoded fp pool) with one scale per page token."""
    axes = tuple(range(2, pages.ndim))
    codes, scale = kv_codec.encode(pages, axes)
    sc = expand_scale(np.asarray(scale), axes, pages.ndim)
    return codes, jnp.asarray(scale), jnp.asarray(kv_codec.decode(codes, sc))


@pytest.mark.pallas
class TestKernelCodecPath:
    @pytest.mark.parametrize("page,pages_per_slot", [(3, 4), (4, 3)])
    def test_codec_kernel_bit_matches_decoded_pool(self, page,
                                                   pages_per_slot):
        """The in-kernel codebook dequant must equal decoding the pool
        up front and running the fp kernel — bit-identical, so the codec
        adds exactly the quantization error and nothing else."""
        rng = np.random.default_rng(page)
        s, h, kh, d = 4, 4, 2, 16
        k_pages, v_pages, table, lengths = random_paged_cache(
            rng, s, kh, d, d, page, pages_per_slot)
        q = jnp.asarray(
            rng.standard_normal((s, h, d)).astype(np.float32)) * d ** -0.5
        kc, ks, kd = encode_pool(k_pages)
        vc, vs, vd = encode_pool(v_pages)
        out = paged_decode_attention(
            q, jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(table),
            jnp.asarray(lengths), k_scales=ks, v_scales=vs,
            codebook=kv_codec.codebook(), interpret=True)
        want = paged_decode_attention(
            q, kd, vd, jnp.asarray(table), jnp.asarray(lengths),
            interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_codec_kernel_mla_second_operand(self):
        """MLA absorbed decode: latent pool (shared K/V codes + scales)
        plus rope-part second operand, all dequantized in-kernel."""
        rng = np.random.default_rng(5)
        s, h, r, dr, page, pps = 3, 4, 8, 4, 3, 4
        c_pages, _, table, lengths = random_paged_cache(
            rng, s, 1, r, r, page, pps)
        c_pages = c_pages[:, :, 0]                       # (n, page, r)
        pe_pages = rng.standard_normal(
            (c_pages.shape[0], page, dr)).astype(np.float32)
        q1 = jnp.asarray(rng.standard_normal((s, h, r)).astype(np.float32))
        q2 = jnp.asarray(rng.standard_normal((s, h, dr)).astype(np.float32))
        scale = (r + dr) ** -0.5
        cc, cs, cd = encode_pool(c_pages)
        pc, ps, pd = encode_pool(pe_pages)
        args = dict(scale=scale, interpret=True)
        out = paged_decode_attention(
            q1, jnp.asarray(cc)[:, :, None], jnp.asarray(cc)[:, :, None],
            jnp.asarray(table), jnp.asarray(lengths), q2,
            jnp.asarray(pc)[:, :, None], k_scales=cs, v_scales=cs,
            k2_scales=ps, codebook=kv_codec.codebook(), **args)
        want = paged_decode_attention(
            q1, cd[:, :, None], cd[:, :, None], jnp.asarray(table),
            jnp.asarray(lengths), q2, pd[:, :, None], **args)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_codec_dummy_sink_stays_inert(self):
        """Page 0 stays all-zero codes / zero scale under the codec;
        poisoning its *codes* must not change any output (the mask never
        admits it) and zero scale keeps its decode exactly zero."""
        rng = np.random.default_rng(9)
        s, h, kh, d = 3, 4, 2, 8
        k_pages, v_pages, table, lengths = random_paged_cache(
            rng, s, kh, d, d, 4, 4)
        k_pages[0] = 0.0
        v_pages[0] = 0.0
        q = jnp.asarray(
            rng.standard_normal((s, h, d)).astype(np.float32)) * d ** -0.5
        kc, ks, _ = encode_pool(k_pages)
        vc, vs, _ = encode_pool(v_pages)
        assert not np.asarray(kc[0]).any() and not np.asarray(ks[0]).any()

        def run(kcodes, vcodes):
            return np.asarray(paged_decode_attention(
                q, jnp.asarray(kcodes), jnp.asarray(vcodes),
                jnp.asarray(table), jnp.asarray(lengths), k_scales=ks,
                v_scales=vs, codebook=kv_codec.codebook(), interpret=True))

        clean = run(kc, vc)
        kc2, vc2 = np.asarray(kc).copy(), np.asarray(vc).copy()
        kc2[0] = 127
        vc2[0] = -127
        poisoned = run(kc2, vc2)
        assert np.isfinite(poisoned).all()
        np.testing.assert_array_equal(clean, poisoned)


# ---------------------------------------------------------------------------
# codec through the serving stack (tests.harness)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    return make_engine()


class TestCodecServing:
    def test_codec_none_is_identity(self, engine):
        """kv_codec="none" over paged lanes is byte-for-byte the plain
        paged path — every generated token identical to the monolithic
        baseline."""
        reqs = mixed_requests(engine, MIXED[:4])
        base = serve(engine, reqs)
        got = serve(engine, reqs, kv_page_size=4, kv_codec="none")
        assert_tokens_identical(got, base, "kv_codec=none")

    @pytest.mark.parametrize("backend", [
        "gathered",
        pytest.param("pallas_paged", marks=pytest.mark.pallas)])
    def test_cluster_first_tokens_exact(self, engine, backend):
        """kv_codec="cluster" is lossy at rest, but the first generated
        token of every request comes out of the (uncompressed) prefill
        forward pass before any page is encoded — it must be exact under
        both attention backends, and every request must still finish."""
        reqs = mixed_requests(engine, MIXED[:4])
        kw = dict(kv_page_size=4, attn_backend=backend)
        base = serve(engine, reqs, **kw)
        got = serve(engine, reqs, kv_codec="cluster", **kw)
        assert set(got) == set(base)
        for i in sorted(base):
            assert got[i][0] == base[i][0], \
                f"first token diverged for request {i}"
            assert len(got[i]) == len(base[i])
