"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, input_specs
from repro.models.api import get_model

REDUCED = {
    "mamba2-780m": dict(num_layers=4, scan_repeats=4, d_model=64,
                        ssm_heads=4, ssm_state=16, ssm_chunk=16, expand=2),
    "gemma2-2b": dict(num_layers=4, scan_repeats=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, window=16),
    "minitron-8b": dict(num_layers=2, scan_repeats=2, d_model=64,
                        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128),
    "phi3-medium-14b": dict(num_layers=2, scan_repeats=2, d_model=64,
                            num_heads=4, num_kv_heads=2, head_dim=16,
                            d_ff=128),
    "h2o-danube-1.8b": dict(num_layers=2, scan_repeats=2, d_model=64,
                            num_heads=4, num_kv_heads=2, head_dim=16,
                            d_ff=128, window=16),
    # capacity_factor=8 -> no token drops, so decode == forward is exact
    # (capacity-bounded MoE drops differently at t=48 vs t=2 by design)
    "mixtral-8x22b": dict(num_layers=2, scan_repeats=2, d_model=64,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64,
                          moe_d_ff=64, num_experts=4, top_k=2, window=16,
                          capacity_factor=8.0),
    "deepseek-v2-236b": dict(num_layers=3, prefix_kinds=("mla_dense",),
                             scan_repeats=2, d_model=64, num_heads=4,
                             num_kv_heads=4, head_dim=16, d_ff=128,
                             moe_d_ff=32, num_experts=4,
                             num_shared_experts=1, top_k=2, kv_lora_rank=16,
                             q_lora_rank=24, rope_head_dim=8,
                             nope_head_dim=16, v_head_dim=16,
                             capacity_factor=8.0),
    "recurrentgemma-2b": dict(num_layers=5, scan_repeats=1,
                              suffix_kinds=("rglru", "rglru"), d_model=64,
                              num_heads=4, num_kv_heads=1, head_dim=16,
                              d_ff=128, lru_width=64, window=16),
    "paligemma-3b": dict(num_layers=2, scan_repeats=2, d_model=64,
                         num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
                         num_vision_tokens=8),
    "whisper-large-v3": dict(num_layers=2, scan_repeats=2, encoder_layers=2,
                             encoder_seq=16, d_model=64, num_heads=4,
                             num_kv_heads=4, head_dim=16, d_ff=128),
}


def reduced(name):
    return get_config(name).scaled(dtype="float32", vocab_size=128,
                                   **REDUCED[name])


def make_batch(cfg, b, s):
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_vision_tokens, cfg.d_model))
            * 0.02, jnp.float32)
    if cfg.family == "audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(REDUCED))
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = reduced(arch)
        api = get_model(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, 2, 32)
        loss, grads = jax.jit(jax.value_and_grad(
            lambda p: api.loss_fn(cfg, p, batch)))(params)
        assert np.isfinite(float(loss))
        gn = sum(float(jnp.abs(g).sum())
                 for g in jax.tree_util.tree_leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_forward_shapes(self, arch):
        cfg = reduced(arch)
        api = get_model(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, 2, 32)
        if cfg.family == "audio":
            logits, _ = api.forward(cfg, params, batch["tokens"],
                                    batch["frame_embeds"])
            assert logits.shape == (2, 32, cfg.vocab_size)
        elif cfg.family == "vlm":
            logits, _ = api.forward(cfg, params, batch["tokens"],
                                    vision_embeds=batch["vision_embeds"])
            assert logits.shape == (2, 32 + cfg.num_vision_tokens,
                                    cfg.vocab_size)
        else:
            logits, _ = api.forward(cfg, params, batch["tokens"])
            assert logits.shape == (2, 32, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["gemma2-2b", "h2o-danube-1.8b",
                                  "mamba2-780m", "deepseek-v2-236b",
                                  "recurrentgemma-2b", "whisper-large-v3"])
def test_decode_matches_forward(arch):
    """prefill(t[:L-1]) + decode(t[L-1]) must equal forward(t)[:, -1]."""
    cfg = reduced(arch)
    api = get_model(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 24
    batch = make_batch(cfg, b, s)
    toks = batch["tokens"]

    if cfg.family == "audio":
        full, _ = api.forward(cfg, params, toks, batch["frame_embeds"])
    elif cfg.family == "vlm":
        full, _ = api.forward(cfg, params, toks,
                              vision_embeds=batch["vision_embeds"])
    else:
        full, _ = api.forward(cfg, params, toks)
    expect = np.asarray(full[:, -1])

    cache = api.init_cache(cfg, b, s + 8)
    if cfg.family == "audio":
        _, cache = api.prefill(cfg, params, toks[:, :-1], cache,
                               batch["frame_embeds"])
        pos = s - 1
    elif cfg.family == "vlm":
        _, cache = api.prefill(cfg, params, toks[:, :-1], cache,
                               vision_embeds=batch["vision_embeds"])
        pos = cfg.num_vision_tokens + s - 1
    else:
        _, cache = api.prefill(cfg, params, toks[:, :-1], cache)
        pos = s - 1
    got, _ = api.decode_step(cfg, params, cache, toks[:, -1:],
                             jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(got[:, 0]), expect,
                               rtol=2e-3, atol=2e-3)


def test_swa_rolling_cache_beyond_window():
    """Decode with a rolling window cache (prompt longer than window)."""
    cfg = reduced("h2o-danube-1.8b").scaled(window=8)
    api = get_model(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    b, s = 1, 20
    toks = jnp.asarray(np.random.default_rng(5).integers(
        0, cfg.vocab_size, (b, s)), jnp.int32)
    full, _ = api.forward(cfg, params, toks)
    cache = api.init_cache(cfg, b, s + 4)   # spec clamps local cache to window
    _, cache = api.prefill(cfg, params, toks[:, :-1], cache)
    got, _ = api.decode_step(cfg, params, cache, toks[:, -1:],
                             jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_input_specs_cover_all_cells():
    for arch in REDUCED:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
            else:
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
