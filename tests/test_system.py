"""End-to-end behaviour tests: the paper's full workflow + serving loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitpack, compression, frequency
from repro.data.pipeline import SyntheticImages, SyntheticLM
from repro.models import reactnet as rn
from repro.models.api import get_model
from repro.train import optimizer as opt
from tests.test_models import reduced


@pytest.fixture(scope="module")
def trained_reactnet():
    """Train a tiny ReActNet for a few dozen steps (shared across tests)."""
    cfg = dataclasses.replace(
        rn.CONFIG, width=32, num_classes=10, image_size=32,
        blocks=((2, 1), (1, 2), (2, 2), (1, 1)))
    params = rn.init_params(cfg, jax.random.PRNGKey(0))
    oc = opt.OptConfig(lr=2e-2, warmup_steps=5, total_steps=60,
                       weight_decay=1e-4, clip_latent=1.5)
    state = opt.init_state(params)
    data = SyntheticImages(10, 32, 32)

    @jax.jit
    def step_fn(params, state, images, labels):
        loss, grads = jax.value_and_grad(
            lambda p: rn.loss_fn(cfg, p, {"images": images,
                                          "labels": labels}))(params)
        params, state, _ = opt.apply_updates(params, grads, state, oc)
        return params, state, loss

    losses = []
    for i in range(60):
        b = data.batch(i)
        params, state, loss = step_fn(params, state,
                                      jnp.asarray(b["images"]),
                                      jnp.asarray(b["labels"]))
        losses.append(float(loss))
    return cfg, params, losses, data


class TestPaperWorkflow:
    def test_bnn_training_learns(self, trained_reactnet):
        _, _, losses, _ = trained_reactnet
        assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])

    def test_trained_kernels_are_skewed(self, trained_reactnet):
        """Claim C1 on actually-trained weights: top-64 well above the
        uniform 12.5%."""
        _, params, _, _ = trained_reactnet
        shares = []
        for name, w in rn.binary_weight_bits(params).items():
            if name.endswith("w3"):
                h = frequency.sequence_histogram(
                    bitpack.kernel_to_sequences(w))
                shares.append(frequency.top_k_share(h, 64))
        assert np.mean(shares) > 0.3, shares

    def test_compressed_deploy_is_lossless(self, trained_reactnet):
        cfg, params, _, data = trained_reactnet
        imgs = jnp.asarray(data.batch(999)["images"])
        base = rn.forward(cfg, params, imgs)
        comp = rn.prepare_compressed(params, cluster=False)
        cfg_c = dataclasses.replace(cfg, conv_mode="compressed")
        got = rn.forward(cfg_c, params, imgs, compressed=comp)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-4, atol=1e-4)

    def test_clustering_accuracy_impact_small(self, trained_reactnet):
        """Claim C3's accuracy side: Hamming-1 clustering barely moves
        predictions on the synthetic task."""
        cfg, params, _, data = trained_reactnet
        b = data.batch(999)
        imgs = jnp.asarray(b["images"])
        base_pred = np.argmax(np.asarray(rn.forward(cfg, params, imgs)), -1)
        comp = rn.prepare_compressed(params, cluster=True)
        cfg_c = dataclasses.replace(cfg, conv_mode="compressed")
        clus_pred = np.argmax(np.asarray(
            rn.forward(cfg_c, params, imgs, compressed=comp)), -1)
        agreement = (base_pred == clus_pred).mean()
        assert agreement > 0.8, agreement

    def test_trained_model_compresses(self, trained_reactnet):
        _, params, _, _ = trained_reactnet
        bits = {k: v for k, v in rn.binary_weight_bits(params).items()
                if k.endswith("w3")}
        _, rep = compression.compress_model(bits, fp_bits=0)
        assert rep.binary_ratio > 1.1, rep.binary_ratio


class TestServingLoop:
    def test_lm_generate_tokens(self):
        cfg = reduced("gemma2-2b")
        api = get_model(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        b, prompt_len, gen = 2, 16, 8
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (b, prompt_len)), jnp.int32)
        cache = api.init_cache(cfg, b, prompt_len + gen)
        logits, cache = api.prefill(cfg, params, toks, cache)
        decode = jax.jit(
            lambda p, c, t, q: api.decode_step(cfg, p, c, t, q))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = []
        for i in range(gen):
            outs.append(np.asarray(tok))
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(prompt_len + i))
            assert np.isfinite(np.asarray(logits)).all()
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        assert np.concatenate(outs, 1).shape == (b, gen)

    def test_lm_train_matches_data_map(self):
        """The synthetic label map is learnable: accuracy on the fixed
        batch goes well above chance after overfitting."""
        cfg = reduced("minitron-8b")
        api = get_model(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        oc = opt.OptConfig(lr=5e-3, warmup_steps=0, weight_decay=0.0,
                           total_steps=100)
        state = opt.init_state(params)
        data = SyntheticLM(cfg.vocab_size, 4, 32)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

        @jax.jit
        def step_fn(params, state):
            loss, grads = jax.value_and_grad(
                lambda p: api.loss_fn(cfg, p, batch))(params)
            new_p, new_s, _ = opt.apply_updates(params, grads, state, oc)
            return new_p, new_s, loss

        for _ in range(60):
            params, state, loss = step_fn(params, state)
        logits, _ = api.forward(cfg, params, batch["tokens"])
        acc = (np.argmax(np.asarray(logits), -1)
               == np.asarray(batch["labels"])).mean()
        assert acc > 0.5, (acc, float(loss))
