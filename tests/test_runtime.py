"""Runtime subsystem tests: decode-cache policies + accounting invariants,
weight-store round-trips (cached tiles == direct fused kernel), slot-level
scheduler batching + mode equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression
from repro.kernels import ops
from repro.runtime import (DecodeTileCache, Scheduler, ServeEngine,
                           WeightStore)
from repro.runtime.decode_cache import POLICIES
from tests.test_models import reduced


def make_store(rng, d=72, f=256, layers=1, cache=None, cluster=False):
    params = {f"l{i}": {"mlp": {"up": rng.standard_normal(
        (d, f)).astype(np.float32)}} for i in range(layers)}
    store = WeightStore(cache if cache is not None else DecodeTileCache())
    store.register_model("m", params, cluster=cluster,
                         select=lambda p, nd: p.endswith("mlp/up"))
    return store, params


class TestDecodeTileCache:
    def test_hit_miss_accounting(self):
        c = DecodeTileCache()
        assert c.get("a") is None and c.misses == 1 and c.hits == 0
        c.put("a", np.zeros(4), streamed_bytes=100)
        assert c.bytes_streamed == 100
        assert c.get("a") is not None
        assert c.hits == 1 and c.bytes_avoided == 100
        assert c.hit_rate() == 0.5

    def test_lru_eviction_order(self):
        v = np.zeros(2, np.uint8)                      # 2 bytes each
        c = DecodeTileCache(capacity_bytes=4)          # holds two entries
        c.put("a", v)
        c.put("b", v)
        c.get("a")                                     # refresh a -> b is LRU
        c.put("c", v)                                  # evicts b, not a
        assert c.evictions == 1
        assert "a" in c and "c" in c and "b" not in c
        assert c.keys()[0] == "a"                      # c most recent

    def test_capacity_bound_and_oversize(self):
        v = np.zeros(8, np.uint8)
        c = DecodeTileCache(capacity_bytes=20)
        for k in range(4):
            c.put(k, v)
        assert c.resident_bytes <= 20 and len(c) == 2
        c.put("big", np.zeros(64, np.uint8))           # larger than capacity
        assert "big" not in c                          # never cached
        assert c.resident_bytes <= 20

    def test_zero_capacity_disables(self):
        c = DecodeTileCache(capacity_bytes=0)
        c.put("a", np.zeros(4))
        assert c.get("a") is None and c.misses == 1

    def test_get_or_decode(self):
        c = DecodeTileCache()
        calls = {"n": 0}

        def decode():
            calls["n"] += 1
            return np.ones(4)

        v1, hit1 = c.get_or_decode("k", decode, streamed_bytes=7)
        v2, hit2 = c.get_or_decode("k", decode, streamed_bytes=7)
        assert not hit1 and hit2 and calls["n"] == 1
        np.testing.assert_array_equal(v1, v2)
        assert c.bytes_streamed == 7 and c.bytes_avoided == 7


class TestEvictionPolicies:
    """Invariants every policy must hold, plus per-policy behaviour."""

    @pytest.mark.parametrize("policy", sorted(POLICIES))
    def test_invariants_under_random_stream(self, policy, rng):
        """resident <= capacity, resident == sum of live entry sizes,
        hits + misses == accesses, bytes_avoided monotone — after every
        single operation of a random access stream."""
        capacity = 64
        c = DecodeTileCache(capacity, policy=policy)
        last_avoided = 0
        universe = [f"k{i}" for i in range(24)]
        sizes = {k: int(rng.integers(1, 33)) for k in universe}
        for _ in range(600):
            key = universe[int(rng.integers(len(universe)))]
            if rng.random() < 0.5:
                c.get(key)
            else:
                c.put(key, np.zeros(sizes[key], np.uint8),
                      streamed_bytes=sizes[key])
            assert c.resident_bytes <= capacity
            assert c.resident_bytes == sum(
                sizes[k] for k in universe if k in c)
            assert c.hits + c.misses == c.accesses
            assert c.bytes_avoided >= last_avoided
            last_avoided = c.bytes_avoided
            assert sorted(c.keys()) == sorted(k for k in universe if k in c)

    @pytest.mark.parametrize("policy", sorted(POLICIES))
    def test_zero_capacity_zero_hit_rate(self, policy):
        c = DecodeTileCache(0, policy=policy)
        for i in range(20):
            c.put(i % 5, np.zeros(4, np.uint8))
            assert c.get(i % 5) is None
        assert c.hit_rate() == 0.0 and len(c) == 0

    @pytest.mark.parametrize("policy", sorted(POLICIES))
    def test_update_existing_key_exact_accounting(self, policy):
        """Regression: re-inserting a key must replace it exactly — the old
        nbytes released, never double-counted against capacity."""
        c = DecodeTileCache(100, policy=policy)
        c.put("a", np.zeros(40, np.uint8))
        c.put("b", np.zeros(30, np.uint8))
        assert c.resident_bytes == 70
        c.put("a", np.zeros(40, np.uint8))       # same size re-insert
        assert c.resident_bytes == 70 and len(c) == 2
        assert c.evictions == 0                  # a 2nd 40 would have evicted
        c.put("a", np.zeros(10, np.uint8))       # shrink in place
        assert c.resident_bytes == 40
        c.put("a", np.zeros(60, np.uint8))       # grow in place, still fits
        assert c.resident_bytes == 90 and c.evictions == 0
        c.put("a", np.zeros(200, np.uint8))      # grow past capacity:
        assert "a" not in c                      # dropped, bytes released
        assert c.resident_bytes == 30 and len(c) == 1

    def test_lfu_keeps_frequent_over_recent(self):
        v = np.zeros(2, np.uint8)
        c = DecodeTileCache(4, policy="lfu")
        c.put("hot", v)
        for _ in range(5):
            c.get("hot")
        c.put("cold1", v)
        c.put("cold2", v)                        # evicts cold1, not hot
        assert "hot" in c and "cold2" in c and "cold1" not in c

    def test_freq_prior_pins_hot_through_cold_scan(self):
        """The paper-skew policy: seeded-hot tiles survive a one-off cold
        scan that flushes LRU completely."""
        v = np.zeros(2, np.uint8)
        hot = [("hot", i) for i in range(4)]
        for policy, expect_hot in (("freq", True), ("lru", False)):
            c = DecodeTileCache(10, policy=policy)
            for k in hot:
                c.seed_frequency(k, 100.0)
            for k in hot:
                c.put(k, v)
            for i in range(40):                  # cold scan, each key once
                c.put(("cold", i), v)
            resident = [k in c for k in hot]
            assert all(resident) == expect_hot
            if expect_hot:                       # hot re-access hits
                hits_before = c.hits
                for k in hot:
                    assert c.get(k) is not None
                assert c.hits == hits_before + len(hot)


class TestWeightStore:
    def test_lazy_tiling(self, rng):
        store, _ = make_store(rng)
        (layer,) = [l for ls in store.layers("m").values() for l in ls]
        assert layer.tiled is None                     # stream-only storage
        store.materialize("m")
        assert layer.tiled is not None                 # tiled on first use

    @pytest.mark.parametrize("cluster", [False, True])
    def test_reconstruction_matches_offline_decompress(self, rng, cluster):
        store, params = make_store(rng, cluster=cluster)
        w = params["l0"]["mlp"]["up"]
        rec = np.asarray(store.materialize("m")["l0"]["mlp"]["up"])
        (layer,) = [l for ls in store.layers("m").values() for l in ls]
        bits = compression.decompress(layer.ct)        # stream-path oracle
        expect = ((bits * 2.0 - 1.0) * layer.scale[:, None]).T
        np.testing.assert_array_equal(rec, expect.astype(np.float32))
        if not cluster:                                # lossless: exact signs
            np.testing.assert_array_equal(rec == 0, np.zeros_like(w, bool))
            np.testing.assert_array_equal(np.signbit(rec), np.signbit(
                np.where(w >= 0, 1.0, -1.0)))

    def test_cached_tiles_match_direct_fused_kernel(self, rng):
        """Round trip: cache-served reconstruction == fused Pallas decode+GEMM
        bit-for-bit (same store, same bits)."""
        store, _ = make_store(rng, d=72, f=128)
        words, tables, meta = store.fused_operands("m", "l0/mlp/up")
        x = rng.standard_normal((5, 72)).astype(np.float32)
        y_fused = np.asarray(ops.compressed_binary_matmul(
            jnp.asarray(x), words, tables, k_true=meta["k_true"],
            n_true=meta["n_true"], codes=meta["codes"]))
        w_rec = np.asarray(store.materialize("m")["l0"]["mlp"]["up"])
        signs = w_rec / np.asarray(meta["scale"])[None, :]   # +-1 matrix
        y_cached = np.where(x >= 0, 1.0, -1.0) @ signs
        np.testing.assert_array_equal(y_fused.astype(np.float32), y_cached)

    def test_tile_reuse_across_steps(self, rng):
        cache = DecodeTileCache()
        store, _ = make_store(rng, layers=2, cache=cache)
        store.materialize("m")
        misses_first = cache.misses
        assert cache.hits == 0 and misses_first == store.n_tiles("m")
        first = store.materialize("m")
        second = store.materialize("m")
        assert cache.misses == misses_first            # no re-decode
        assert cache.hits == 2 * misses_first
        # memoised device arrays are reused, not rebuilt
        for a, b in zip(jax.tree_util.tree_leaves(first),
                        jax.tree_util.tree_leaves(second)):
            assert a is b

    def test_multi_model_keys_dont_collide(self, rng):
        cache = DecodeTileCache()
        store = WeightStore(cache)
        for mid in ("a", "b"):
            store.register_model(
                mid, {"mlp": {"up": rng.standard_normal(
                    (36, 64)).astype(np.float32)}})
        store.materialize("a")
        store.materialize("b")
        assert cache.misses == store.n_tiles("a") + store.n_tiles("b")
        assert cache.hits == 0


class TestScheduler:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = reduced("minitron-8b")
        params = jax.tree_util.tree_map(
            np.asarray,
            __import__("repro.models.api", fromlist=["get_model"])
            .get_model(cfg).init_params(cfg, jax.random.PRNGKey(0)))
        return ServeEngine(cfg, params, compress=True)

    def test_engine_compresses_scan_mlps(self, engine):
        assert engine.compressed
        assert engine.report["layers"] >= 2            # stacked repeats split

    def test_wave_serving_and_cache_hit_rate(self, engine):
        engine.cache.reset_counters()
        sched = Scheduler(engine, batch_size=2, log_every=0)
        rng = np.random.default_rng(1)
        for _ in range(2):
            sched.submit(rng.integers(0, engine.cfg.vocab_size, 8), 12)
        done = sched.run()
        assert len(done) == 2
        assert all(len(r.generated) == 12 and r.done for r in done)
        # decoded tiles are reused, not re-decoded per token
        assert engine.cache.hit_rate() >= 0.9
        assert engine.metrics.tokens_generated == 24

    def test_bucketing_splits_waves(self, engine):
        sched = Scheduler(engine, batch_size=4, buckets=(8, 16),
                          mode="wave")
        rng = np.random.default_rng(2)
        sched.submit(rng.integers(0, engine.cfg.vocab_size, 6), 2)
        sched.submit(rng.integers(0, engine.cfg.vocab_size, 12), 2)
        sched.submit(rng.integers(0, engine.cfg.vocab_size, 7), 2)
        waves_before = engine.metrics.waves
        done = sched.run()
        assert len(done) == 3
        # lengths 6 and 7 share the 8-bucket; 12 goes to the 16-bucket
        assert engine.metrics.waves - waves_before == 2

    def test_mode_and_order_equivalence(self, engine):
        """Same request set -> identical tokens under wave mode,
        continuous mode, and shuffled admission order: per-slot exact
        positions make generation independent of batch neighbours."""
        rng = np.random.default_rng(5)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, L), g)
                for L, g in [(5, 7), (8, 2), (11, 5), (6, 9)]]

        def serve(mode, order):
            sched = Scheduler(engine, batch_size=2, mode=mode,
                              buckets=(16,))
            rids = {}
            for i in order:
                rids[sched.submit(*reqs[i]).rid] = i
            done = sched.run()
            return {rids[r.rid]: tuple(r.generated) for r in done}

        wave = serve("wave", [0, 1, 2, 3])
        cont = serve("continuous", [0, 1, 2, 3])
        shuf = serve("continuous", [2, 0, 3, 1])
        assert wave == cont == shuf
        assert sorted(len(v) for v in wave.values()) == [2, 5, 7, 9]

    def test_admit_on_retire_raises_occupancy(self, engine):
        """Heterogeneous budgets: continuous batching refills retired
        slots mid-decode, finishing in fewer decode steps than wave mode
        while producing the same tokens."""
        rng = np.random.default_rng(6)
        reqs = [(rng.integers(0, engine.cfg.vocab_size, 6), g)
                for g in (2, 8, 3, 7)]
        stats = {}
        for mode in ("wave", "continuous"):
            sched = Scheduler(engine, batch_size=2, mode=mode)
            steps0 = engine.metrics.decode_steps
            slot0 = engine.metrics.slot_steps
            cap0 = engine.metrics.capacity_steps
            for r in reqs:
                sched.submit(*r)
            done = sched.run()
            assert len(done) == 4
            stats[mode] = (engine.metrics.decode_steps - steps0,
                           engine.metrics.slot_steps - slot0,
                           engine.metrics.capacity_steps - cap0)
        # same generated-token total, fewer decode steps, higher occupancy
        assert stats["continuous"][1] == stats["wave"][1]
        assert stats["continuous"][0] < stats["wave"][0]
        occ = {m: s[1] / s[2] for m, s in stats.items()}
        assert occ["continuous"] > occ["wave"]

    def test_serving_logits_match_direct_eval(self):
        """Bit-identical round trip at the logits level: scheduler serving
        on cache-reconstructed weights == a direct decode loop on offline
        stream-decompressed weights."""
        cfg = reduced("minitron-8b")
        from repro.models.api import get_model
        params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(1))
        engine = ServeEngine(cfg, params, compress=True)
        prompt = np.random.default_rng(3).integers(0, cfg.vocab_size, 8)
        sched = Scheduler(engine, batch_size=1, buckets=(8,))
        req = sched.submit(prompt, 6)
        sched.run()

        # direct eval: same BNN cfg, weights rebuilt without the cache
        cfg_b = engine.cfg
        api = get_model(cfg_b)
        direct = {}
        for path, stack in engine.store.layers("lm").items():
            recs = []
            for layer in stack:
                bits = compression.decompress(layer.ct)
                recs.append((((bits * 2.0 - 1.0) * layer.scale[:, None]).T
                             ).astype(np.float32))
            direct[path] = np.stack(recs) if len(recs) > 1 else recs[0]

        def sub(p, leaf):
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in p)
            return jnp.asarray(direct[name]) if name in direct else leaf

        params_direct = jax.tree_util.tree_map_with_path(sub, params)
        cache = api.init_cache(cfg_b, 1, 8 + 6)
        toks = jnp.asarray(prompt[None].astype(np.int32))
        logits, kv = api.prefill(cfg_b, params_direct, toks, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = []
        for i in range(6):
            out.append(int(tok[0, 0]))
            logits, kv = api.decode_step(cfg_b, params_direct, kv, tok,
                                         jnp.int32(8 + i))
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        assert req.generated == out
