"""jit-compiled step builders: train_step / prefill / serve_step.

These are what the dry-run lowers and what the real launchers execute.  All
sharding is decided here (params/batch/cache shardings from dist.sharding)
so the model code stays mesh-agnostic.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.dist.compression_comm import compress_grads, init_error_feedback
from repro.models.api import get_model
from repro.train import optimizer as opt


def train_state_specs(cfg, mesh, *, fsdp: bool = True):
    """ShapeDtypeStructs + shardings of (params, opt_state) without
    allocating anything (dry-run path)."""
    api = get_model(cfg)
    params_sds = jax.eval_shape(
        functools.partial(api.init_params, cfg), jax.random.PRNGKey(0))
    p_shard = shd.params_shardings(params_sds, mesh, fsdp=fsdp)
    opt_sds = jax.eval_shape(opt.init_state, params_sds)
    o_shard = {
        "step": NamedSharding(mesh, P()),
        "mu": shd.params_shardings(params_sds, mesh, fsdp=fsdp),
        "nu": shd.params_shardings(params_sds, mesh, fsdp=fsdp),
    }
    return (params_sds, p_shard), (opt_sds, o_shard)


def build_train_step(cfg, mesh, oc: opt.OptConfig | None = None,
                     *, fsdp: bool = True, grad_compression: str = "none",
                     donate: bool = True, batch_sds=None):
    """Returns (jitted step, in_shardings pytree builder).

    step(state, batch) -> (state, loss); state = {"params", "opt"}.
    ``batch_sds``: optional pytree of ShapeDtypeStructs — when given, the
    batch in_shardings are fixed (DP over the leading axis) so the dry-run
    lowers with correctly-sharded inputs instead of replicated defaults.
    """
    api = get_model(cfg)
    oc = oc or opt.OptConfig()
    (p_sds, p_shard), (o_sds, o_shard) = train_state_specs(cfg, mesh,
                                                           fsdp=fsdp)

    if grad_compression != "none":
        raise ValueError(
            "grad compression needs local (unreduced) gradients; use "
            "build_compressed_dp_train_step (pure-DP shard_map path)")

    def step(state, batch):
        params = state["params"]

        def loss_of(p):
            return api.loss_fn(cfg, p, batch)

        loss, grads = jax.value_and_grad(loss_of)(params)
        new_params, new_opt, metrics = opt.apply_updates(
            params, grads, state["opt"], oc)
        return {"params": new_params, "opt": new_opt}, loss

    state_shardings = {"params": p_shard, "opt": o_shard}
    batch_spec = (shd.batch_shardings(batch_sds, mesh)
                  if batch_sds is not None else None)
    jit_step = jax.jit(
        step,
        in_shardings=(state_shardings, batch_spec),
        out_shardings=(state_shardings, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return jit_step, state_shardings


def build_compressed_dp_train_step(loss_fn, mesh, oc: opt.OptConfig,
                                   *, mode: str = "onebit"):
    """Data-parallel train step with compressed gradient exchange.

    This is the honest 1-bit/int8 path: the whole step runs under
    ``shard_map`` over the DP axes, so ``value_and_grad`` yields *local*
    gradients and the only cross-replica traffic is the packed sign words
    (+ scales) of compression_comm — the collective bytes the roofline sees.

    Params are replicated across DP (suits the ~100M-scale BNN/example
    models this path serves); TP meshes should use build_train_step.

    loss_fn(params, batch) -> scalar local loss.
    state = {"params", "opt", "ef"}; returns (step_fn, state_shardings).
    """
    from jax.experimental.shard_map import shard_map

    axes = shd.batch_axes(mesh)
    repl = NamedSharding(mesh, P())

    def step(state, batch):
        def local(params, opt_state, ef, batch):
            with shd.no_mesh():   # shard_map body is already per-shard
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads, new_ef = compress_grads(grads, ef, axes, mode=mode)
            new_params, new_opt, _ = opt.apply_updates(
                params, grads, opt_state, oc)
            return new_params, new_opt, new_ef, jax.lax.pmean(loss, axes)

        p_specs = jax.tree_util.tree_map(lambda _: P(), state["params"])
        o_specs = jax.tree_util.tree_map(lambda _: P(), state["opt"])
        e_specs = jax.tree_util.tree_map(lambda _: P(), state["ef"])
        b_specs = jax.tree_util.tree_map(
            lambda _: P(axes), batch)
        new_p, new_o, new_e, loss = shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, o_specs, e_specs, b_specs),
            out_specs=(p_specs, o_specs, e_specs, P()),
            check_rep=False,
        )(state["params"], state["opt"], state["ef"], batch)
        return {"params": new_p, "opt": new_o, "ef": new_e}, loss

    state_shardings = jax.tree_util.tree_map(lambda _: repl, {"_": 0})
    return jax.jit(step, donate_argnums=(0,)), state_shardings


def init_train_state(cfg, mesh, key, *, fsdp: bool = True,
                     grad_compression: str = "none"):
    """Materialise sharded params + optimizer state on the mesh."""
    api = get_model(cfg)
    (p_sds, p_shard), (_, o_shard) = train_state_specs(cfg, mesh, fsdp=fsdp)
    init = jax.jit(functools.partial(api.init_params, cfg),
                   out_shardings=p_shard)
    params = init(key)
    opt_state = jax.jit(opt.init_state, out_shardings=o_shard)(params)
    state = {"params": params, "opt": opt_state}
    if grad_compression != "none":
        ef_shard = p_shard
        state["ef"] = jax.jit(init_error_feedback,
                              out_shardings=ef_shard)(params)
    return state


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def build_serve_steps(cfg, mesh, batch: int, max_len: int,
                      *, fsdp: bool = False):
    """(prefill_fn, decode_fn, cache_specs, cache_shardings)."""
    api = get_model(cfg)
    params_sds = jax.eval_shape(
        functools.partial(api.init_params, cfg), jax.random.PRNGKey(0))
    p_shard = shd.params_shardings(params_sds, mesh, fsdp=fsdp)
    cache_sds = api.init_cache_specs(cfg, batch, max_len)
    c_shard = shd.cache_shardings(cache_sds, mesh)

    def prefill_fn(params, tokens, cache, *extra):
        if cfg.family == "vlm":
            return api.prefill(cfg, params, tokens, cache,
                               vision_embeds=extra[0])
        return api.prefill(cfg, params, tokens, cache, *extra)

    def decode_fn(params, cache, tokens, pos):
        return api.decode_step(cfg, params, cache, tokens, pos)

    logits_shard = NamedSharding(mesh, shd.safe_spec(
        mesh, (batch, 1, cfg.vocab_size), "batch", None, "model"))
    tok_shard = NamedSharding(mesh, shd.safe_spec(
        mesh, (batch, 1), "batch", None))
    extra_shards = ()
    if cfg.family in ("vlm", "audio"):   # stubbed-frontend embeddings
        extra_shards = (NamedSharding(mesh, shd.safe_spec(
            mesh, (batch, 1, cfg.d_model), "batch", None, None)),)
    prefill_jit = jax.jit(
        prefill_fn,
        in_shardings=(p_shard, tok_shard, c_shard) + extra_shards,
        out_shardings=(logits_shard, c_shard))
    decode_jit = jax.jit(
        decode_fn,
        in_shardings=(p_shard, c_shard, tok_shard, NamedSharding(mesh, P())),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,))
    return prefill_jit, decode_jit, (params_sds, p_shard), (cache_sds, c_shard)
