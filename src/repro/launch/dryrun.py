import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before jax initialises devices (contract in
# the brief): the dry-run — and only the dry-run — sees 512 host devices.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * jit(step).lower(**ShapeDtypeStructs).compile() must succeed on the
    single-pod 16x16 mesh and the 2x16x16 multi-pod mesh;
  * per cell we record memory_analysis(), cost_analysis() and the
    collective-op byte census parsed from the optimised HLO — the roofline
    harness (benchmarks/roofline.py) consumes these artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single          # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
# NOTE: no `from __future__ import annotations` here — the XLA_FLAGS lines
# must be the first statements of the module (jax locks device count on
# first init), and future-imports may not follow them.

import argparse
import functools
import json
import re
import time
import traceback

import jax

from repro.configs import base as cfgs
from repro.dist import sharding as shd
from repro.launch import steps as steps_mod
from repro.launch.hlo_census import HloCensus
from repro.launch.mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}




def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def model_flops(cfg, shape: cfgs.ShapeConfig) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful-training-FLOPs yardstick.

    For serve cells (no backward) the yardstick is 2*N*D.
    """
    api_params = jax.eval_shape(
        functools.partial(_init_for(cfg), cfg), jax.random.PRNGKey(0))
    total = sum(x.size for x in jax.tree_util.tree_leaves(api_params))
    n_active = total
    if cfg.num_experts:
        # subtract inactive routed-expert params
        f = cfg.moe_d_ff or cfg.d_ff
        per_expert = 3 * cfg.d_model * f
        layers_moe = sum(1 for k in cfg.layer_kinds if k in
                         ("swa_moe", "mla_moe", "moe"))
        n_active = total - layers_moe * per_expert * (cfg.num_experts
                                                      - cfg.top_k)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens, total, n_active


def _init_for(cfg):
    from repro.models.api import get_model
    return get_model(cfg).init_params


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build + lower + compile one cell; returns the artifact dict."""
    cfg = cfgs.get_config(arch)
    shape = cfgs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = cfgs.input_specs(cfg, shape)
    t0 = time.monotonic()

    with shd.use_mesh(mesh):
        if shape.kind == "train":
            (p_sds, p_shard), (o_sds, o_shard) = steps_mod.train_state_specs(
                cfg, mesh, fsdp=True)
            state_sds = {"params": p_sds, "opt": o_sds}
            jit_step, _ = steps_mod.build_train_step(
                cfg, mesh, donate=False, batch_sds=specs)
            lowered = jit_step.lower(state_sds, specs)
        else:
            prefill_jit, decode_jit, (p_sds, _), (c_sds, _) = \
                steps_mod.build_serve_steps(cfg, mesh, shape.global_batch,
                                            shape.seq_len)
            if shape.kind == "prefill":
                extra = []
                if cfg.family == "vlm":
                    extra = [specs["vision_embeds"]]
                if cfg.family == "audio":
                    extra = [specs["frame_embeds"]]
                lowered = prefill_jit.lower(p_sds, specs["tokens"], c_sds,
                                            *extra)
            else:
                lowered = decode_jit.lower(p_sds, c_sds, specs["tokens"],
                                           specs["pos"])
        compiled = lowered.compile()

    t_compile = time.monotonic() - t0
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    census = HloCensus(hlo)
    coll = census.collective_bytes()
    mf, n_total, n_active = model_flops(cfg, shape)

    art = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "compile_s": round(t_compile, 1),
        # trip-weighted HLO census (per device, per step) — cost_analysis
        # counts while bodies once, so it is recorded only as *_raw
        "flops": census.flops(),
        "bytes_accessed": census.hbm_bytes("tpu"),
        "bytes_accessed_cpu_granularity": census.hbm_bytes("cpu"),
        "flops_raw_costanalysis": float(cost.get("flops", -1)),
        "bytes_raw_costanalysis": float(cost.get("bytes accessed", -1)),
        "collectives": coll,
        "model_flops": mf,
        "params_total": int(n_total),
        "params_active": int(n_active),
        "memory": {
            k: getattr(mem, k)
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if mem is not None and hasattr(mem, k)
        },
        "hlo_bytes": len(hlo),
    }
    return art


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             save_hlo: bool = False) -> dict:
    mesh_tag = "multi" if multi_pod else "single"
    name = f"{arch}__{shape_name}__{mesh_tag}"
    runnable, why = cfgs.cell_is_runnable(arch, shape_name)
    if not runnable:
        art = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": why}
        print(f"[dryrun] {name}: {why}")
    else:
        try:
            art = lower_cell(arch, shape_name, multi_pod)
            art["status"] = "ok"
            print(f"[dryrun] {name}: OK  compile={art['compile_s']}s  "
                  f"GFLOPs={art['flops']/1e9:.1f}  "
                  f"coll={art['collectives']['total']/1e9:.3f}GB")
        except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
            art = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "status": f"FAIL: {type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] {name}: FAILED — {e}")
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, name + ".json"), "w") as f:
        json.dump(art, f, indent=1)
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=cfgs.ARCH_NAMES)
    ap.add_argument("--shape", choices=list(cfgs.SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    archs = cfgs.ARCH_NAMES if args.all or not args.arch else [args.arch]
    shapes = list(cfgs.SHAPES) if args.all or not args.shape else [args.shape]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.out))
    bad = [r for r in results
           if r["status"] not in ("ok",) and "skip" not in r["status"]]
    print(f"[dryrun] {len(results)} cells, {len(bad)} failures")
    raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
