"""Production meshes (a FUNCTION, not a module constant — importing this
module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Target: TPU v5e pods. 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this process actually has (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
