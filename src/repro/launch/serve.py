"""Serving driver on the compressed-weight runtime.

Batched requests flow through the slot-level continuous-batching scheduler
(per-slot prefill -> vmapped per-slot decode -> admit-on-retire); the
model's MLP projections are binarised, Huffman-compressed into the
WeightStore, and reconstructed each step from the decode-tile cache —
after the first step every tile is a cache hit, so weights are *reused*,
not re-decoded per token.  ``--mode wave`` reproduces the old
wave-granular scheduling (token-identical, lower slot occupancy);
``--policy`` picks the decode-cache eviction policy;
``--prefill-chunk`` interleaves prompt chunks with decode steps and
``--kv-page-size`` backs the KV lanes with demand-allocated pages —
both token-identical to the monolithic defaults.  ``--attn-backend
pallas_paged`` decodes straight over the page pool with the in-kernel
paged-attention kernel (zero per-step KV gather/scatter copies; also
token-identical).  Combining ``--attn-backend pallas_paged`` with
``--prefill-chunk`` engages the unified **mixed-step** path: prefill
chunks and decode tokens of every slot ride one ragged batched trace
per iteration, chunks write straight into the page pools, and the
serve summary's KV gather counters read zero for prefill *and* decode.
``--kv-codec cluster`` stores the page pools as int8 codebook codes plus
per-token scales (decoded in-kernel under ``pallas_paged``, at gather
under ``gathered``) — ~4x resident-KV compression at a reported
reconstruction-error bound, with the at-rest Huffman ratio of the
resident codes printed in the summary.  ``--prefix-share`` caches
completed prefills' KV pages in a refcounted prefix index so requests
extending a cached prefix (generate them with ``--shared-prefix-len``)
map the shared pages and skip that prefill work — token-identical, with
copy-on-write guarding every shared page.  ``--kernel-tune auto``
hardware-tiles the page pools toward the TPU's (8, 128) register tiles
and sweeps the kernel's ``(q_block, pages_per_step)`` launch shape on
the live model/page-size (memoised per ``(arch, page, Q)``), again
token-identical to ``off``.

Observability: ``--trace-out trace.json`` records every request's
lifecycle span tree (queued -> admitted -> prefill chunks -> decode ->
retired) plus engine phase spans as Chrome-trace JSON — open it in
``chrome://tracing`` or https://ui.perfetto.dev (``--trace-jsonl``
additionally dumps the raw events one-per-line).  ``--metrics-out
metrics.prom`` dumps every serving counter/gauge/histogram in
Prometheus text-exposition format.  Both are validated before exit
(span count == completed requests; the .prom text re-parses) and
neither changes generated tokens.  ``--cache-mb auto`` sweeps the
materialize access pattern over a capacity grid and serves with the
recommended hit-rate-cliff knee capacity.

  PYTHONPATH=src python -m repro.launch.serve --scale tiny
  PYTHONPATH=src python -m repro.launch.serve --scale tiny \
      --trace-out /tmp/trace.json --metrics-out /tmp/metrics.prom \
      --cache-mb auto
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --batch 4 --prompt-len 64 --gen 32 --requests 8 --policy freq \
      --prefill-chunk 16 --kv-page-size 16 --attn-backend pallas_paged
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import base as cfgs
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.train import tiny_config
from repro.models.api import get_model
from repro.runtime import (Scheduler, ServeEngine, Telemetry, parse_prom,
                           recommend_store_capacity)
from repro.runtime.decode_cache import POLICIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=cfgs.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests to serve (default: one full batch)")
    ap.add_argument("--scale", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--cache-mb", type=str, default=None,
                    help="decode-tile cache capacity in MiB (omit = "
                         "unbounded; 0 = caching disabled, the no-cache "
                         "baseline; 'auto' = sweep the materialize access "
                         "pattern over a capacity grid and serve with the "
                         "hit-rate-cliff knee capacity)")
    ap.add_argument("--policy", choices=sorted(POLICIES), default="lru",
                    help="decode-cache eviction policy")
    ap.add_argument("--mode", choices=["continuous", "wave"],
                    default="continuous",
                    help="slot scheduling: continuous (admit-on-retire) or "
                         "wave (drain before admitting, the old behavior)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split prompts into chunks of this many tokens, "
                         "interleaved with decode steps (omit = monolithic "
                         "batch-1 prefill at admission); with --attn-"
                         "backend pallas_paged this engages the unified "
                         "mixed-step path (chunks + decode tokens in one "
                         "batched trace, zero prefill/decode KV copies)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prefill tokens per scheduler iteration "
                         "(default: one chunk); bounds decode-latency "
                         "impact of long prompts.  On the mixed-step "
                         "path each prefilling slot advances at most one "
                         "chunk per iteration, so budget beyond "
                         "batch * chunk has no additional effect")
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help="back KV lanes with pages of this many tokens, "
                         "allocated on demand (omit = monolithic "
                         "slot_len lanes)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="logical page-pool size (default: fully backs "
                         "every slot; smaller = overcommit, admission "
                         "defers when reservations fail)")
    ap.add_argument("--attn-backend", choices=["gathered", "pallas_paged"],
                    default="gathered",
                    help="how decode reads paged KV: gathered (copy pages "
                         "into contiguous views each step, the reference) "
                         "or pallas_paged (in-kernel paged attention, "
                         "zero per-step cache copies; needs "
                         "--kv-page-size)")
    ap.add_argument("--kv-codec", choices=["none", "cluster"],
                    default="none",
                    help="KV page-pool codec: none (fp pages, bit-exact) "
                         "or cluster (pages stored as int8 codebook codes "
                         "+ per-token scales, decoded in-kernel / at "
                         "gather; ~4x resident-KV compression at a "
                         "bounded reconstruction error; needs "
                         "--kv-page-size)")
    ap.add_argument("--kernel-tune", type=str, default=None,
                    help="paged-attention kernel launch shape (needs "
                         "--attn-backend pallas_paged): 'off' (default, "
                         "identity layout), 'auto' (sweep (q_block, "
                         "pages_per_step) on the live model/page shapes, "
                         "memoised per (arch, page, Q), and serve with "
                         "hardware-tiled pools), or explicit "
                         "'QB[,PPS]' — all token-identical")
    ap.add_argument("--prefix-share", action="store_true",
                    help="cache completed prefills' KV pages in a prefix "
                         "index; requests extending a cached prefix map "
                         "the shared (refcounted) pages into their page "
                         "table and skip that prefill work entirely, "
                         "with copy-on-write protecting shared pages — "
                         "token-identical to serving each request "
                         "privately (needs --kv-page-size and "
                         "--prefill-chunk)")
    ap.add_argument("--prompt-pattern", type=int, default=0,
                    help="tile each request's prompt from its own "
                         "repeating pattern of this many tokens (0 = "
                         "fully random prompts); repetitive prompts are "
                         "the regime where --speculate ngram pays, since "
                         "the drafter continues patterns the history "
                         "already contains")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="generate request prompts sharing a common "
                         "prefix of this many tokens (0 = fully random "
                         "prompts); pair with --prefix-share to see "
                         "reuse, or without it for the baseline")
    ap.add_argument("--speculate", default="off",
                    help="speculative decoding drafter: 'off' (default), "
                         "'ngram' (prompt/history n-gram matcher, no "
                         "extra weights), or 'draft'/'draft:<arch>' (a "
                         "tiny draft model sharing the engine's weight "
                         "store).  Each slot proposes up to --draft-k "
                         "tokens per step, verified in the same ragged "
                         "batched invocation; greedy verification is "
                         "token-identical to --speculate off")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="max draft tokens proposed per slot per step "
                         "(bounds the verify width at 1 + k)")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable async next-layer tile prefetch")
    ap.add_argument("--no-compress", action="store_true",
                    help="uncompressed baseline on the same scheduler")
    ap.add_argument("--log-every", type=int, default=16)
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write per-request lifecycle spans + engine phase "
                         "spans as Chrome-trace JSON to this path (open in "
                         "chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--trace-jsonl", type=str, default=None,
                    help="additionally dump the raw trace events as JSONL "
                         "(one event per line) to this path")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write every serving counter/gauge/histogram in "
                         "Prometheus text-exposition format to this path")
    args = ap.parse_args()

    cfg = tiny_config(args.arch) if args.scale == "tiny" \
        else cfgs.get_config(args.arch)
    mesh = make_host_mesh()
    n_requests = args.requests or args.batch
    cache_auto = args.cache_mb == "auto"
    cache_bytes = None if args.cache_mb is None or cache_auto \
        else int(float(args.cache_mb) * 2 ** 20)
    # trace spans only when a trace sink was asked for; phase histograms
    # ride along whenever any telemetry output is requested.  The default
    # (no flags) serves with the zero-cost null recorder.
    telemetry = Telemetry(trace=bool(args.trace_out or args.trace_jsonl)) \
        if (args.trace_out or args.trace_jsonl or args.metrics_out) \
        else None

    with shd.use_mesh(mesh):
        params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
        engine = ServeEngine(cfg, params, compress=not args.no_compress,
                             cache_bytes=cache_bytes,
                             cache_policy=args.policy,
                             prefetch=not args.no_prefetch,
                             telemetry=telemetry)
        if cache_auto:
            if not engine.compressed:
                raise SystemExit("--cache-mb auto needs the compressed "
                                 "path; drop --no-compress")
            rec = recommend_store_capacity(engine.store, engine.model_id,
                                           policy=args.policy)
            engine.cache.capacity_bytes = rec["capacity"]
            print(f"cache autotune: working set "
                  f"{rec['working_set'] / 2 ** 20:.2f} MiB -> recommended "
                  f"capacity {rec['capacity'] / 2 ** 20:.2f} MiB "
                  f"({rec['fraction']:.2f}x, projected hit rate "
                  f"{rec['hit_rate'] * 100:.1f}%, best "
                  f"{rec['best_rate'] * 100:.1f}%)")
        if engine.compressed:
            rep = engine.report
            print(f"weight store: {rep['layers']} compressed MLP tensors, "
                  f"{rep['packed_bytes']} packed bytes -> "
                  f"{rep['stream_bytes']} stream bytes "
                  f"({rep['ratio_stream']:.3f}x)")
        else:
            print(f"weight store: no compressible MLPs in {args.arch}; "
                  "serving uncompressed")

        sched = Scheduler(engine, batch_size=args.batch, mode=args.mode,
                          prefill_chunk=args.prefill_chunk,
                          prefill_budget=args.prefill_budget,
                          kv_page_size=args.kv_page_size,
                          kv_pages=args.kv_pages,
                          attn_backend=args.attn_backend,
                          kv_codec=args.kv_codec,
                          prefix_share=args.prefix_share,
                          kernel_tune=args.kernel_tune,
                          speculate=args.speculate,
                          draft_k=args.draft_k,
                          log_every=args.log_every)
        rng = np.random.default_rng(0)
        shared_len = min(args.shared_prefix_len, args.prompt_len - 1)
        common = rng.integers(0, cfg.vocab_size, max(shared_len, 0))
        for _ in range(n_requests):
            tail_len = args.prompt_len - len(common)
            if args.prompt_pattern:
                pat = rng.integers(0, cfg.vocab_size, args.prompt_pattern)
                tail = np.tile(pat, -(-tail_len // len(pat)))[:tail_len]
            else:
                tail = rng.integers(0, cfg.vocab_size, tail_len)
            sched.submit(np.concatenate([common, tail]), args.gen)

        t0 = time.monotonic()
        completed = sched.run()
        wall = time.monotonic() - t0

    m = engine.metrics
    assert len(completed) == n_requests
    assert all(len(r.generated) == r.max_new_tokens for r in completed)
    print(f"served {len(completed)} requests in {wall:.2f}s "
          f"({args.mode} slots, batch {args.batch}, "
          f"{m.prefills} prefills)")
    ttfts = [r.first_token_latency() for r in completed]
    ttft = sum(t for t in ttfts if t is not None) / max(len(ttfts), 1)
    print(f"prefill: {m.prefill_s:.2f}s total "
          f"(mean time-to-first-token {ttft * 1000:.0f} ms)")
    for label, hist, unit in (("ttft", m.ttft_hist, 1000.0),
                              ("tpot", m.tpot_hist, 1000.0),
                              ("e2e ", m.e2e_hist, 1000.0)):
        if hist.n:
            p50, p90, p99 = hist.percentiles(50, 90, 99)
            print(f"{label}   : p50 {p50 * unit:.1f} ms | "
                  f"p90 {p90 * unit:.1f} ms | p99 {p99 * unit:.1f} ms "
                  f"(n={hist.n})")
    if m.prefill_chunks:
        print(f"chunked prefill: {m.prefill_chunks} chunks of "
              f"<= {args.prefill_chunk} tokens, "
              f"{m.prefill_chunk_ms():.1f} ms/chunk, decode stalled "
              f"{m.decode_stall_s:.2f}s behind chunks")
    print(f"decode : {m.ms_per_token():.1f} ms/step "
          f"({m.tokens_per_s():.1f} tok/s, "
          f"occupancy {m.occupancy() * 100:.0f}%)")
    if m.pages_total:
        print(f"kv pages: {args.kv_page_size}-token pages, pool "
              f"{m.pages_total}, mean occupancy "
              f"{m.page_occupancy() * 100:.0f}%")
        print(f"kv gather ({sched.attn_backend} backend): "
              f"{m.kv_gather_bytes} bytes copied on the decode hot path, "
              f"{m.kv_gather_bytes_avoided} avoided in-kernel")
        print(f"prefill gather: {m.kv_prefill_gather_bytes} bytes copied "
              f"installing prefilled caches, "
              f"{m.kv_prefill_gather_bytes_avoided} avoided by "
              f"mixed-step in-pool prefill")
    if sched.kernel_tune != "off" and sched._pool is not None:
        pool = sched._pool
        print(f"kernel tune ({sched.kernel_tune}): q_block="
              f"{pool.q_block or 'whole-Q'} pages_per_step="
              f"{pool.pages_per_step}, hardware-tiled pools "
              f"({pool.page_size}-token pages padded to "
              f"{pool.page_rows} rows), {m.kernel_qblock_rounded} "
              f"q_block roundings")
    if sched.prefix_share:
        pool = sched._pool
        print(f"prefix share: {m.prefix_hits} hits, "
              f"{m.prefix_tokens_reused} prompt tokens served from "
              f"cached pages ({m.prefill_chunks_avoided} prefill chunks "
              f"avoided), {m.prefix_cow_copies} copy-on-write page "
              f"copies, {m.prefix_evictions} index evictions")
        print(f"prefix index: {pool.prefix.n_nodes} cached pages "
              f"covering {pool.prefix.tokens_cached} tokens")
    if args.kv_codec == "cluster":
        pool = sched._pool
        print(f"kv codec (cluster): page {pool.page_bytes_fp} fp bytes -> "
              f"{pool.page_bytes_resident} resident bytes "
              f"({m.kv_capacity_multiplier():.2f}x effective capacity, "
              f"{m.kv_bytes_avoided} resident bytes avoided)")
        print(f"kv codec error bound: {m.kv_codec_error_bound:.3e} "
              f"(max per-token scale / 254)")
        # at-rest Huffman layer over the resident int8 codes (report
        # only — the pool itself stays raw int8 for in-kernel decode)
        codes = (jax.tree_util.tree_leaves(pool.kcache)
                 if pool.backend == "pallas_paged" else pool.pages)
        codes = [np.asarray(c) for c in codes if c.dtype == np.int8]
        if codes:
            from repro.kernels import kv_codec as kvc
            rep = kvc.huffman_report(
                np.concatenate([c.ravel() for c in codes]))
            print(f"kv codec at-rest huffman: {rep['avg_bits']:.2f} "
                  f"bits/code ({rep['ratio']:.2f}x vs int8), clustered "
                  f"{rep['clustered_avg_bits']:.2f} bits "
                  f"({rep['clustered_ratio']:.2f}x)")
    if engine.compressed:
        st = engine.cache.stats()
        print(f"decode-tile cache ({st['policy']}): {st['hits']} hits / "
              f"{st['misses']} misses / {st['evictions']} evictions")
        print(f"cache hit-rate: {st['hit_rate'] * 100:.1f}%")
        print(f"compressed bytes streamed: {st['bytes_streamed']}; "
              f"bytes avoided by cache: {st['bytes_avoided']}")
        if engine.store.prefetch_dispatched:
            print(f"tile prefetch: {engine.store.prefetch_dispatched} "
                  f"dispatched, {engine.store.prefetch_used} consumed")
    if m.spec_rounds:
        total = sum(len(r.generated) for r in completed)
        print(f"speculative ({sched.speculate}, k={sched.draft_k}): "
              f"{m.spec_accepted_tokens}/{m.spec_draft_tokens} draft "
              f"tokens accepted ({m.spec_acceptance_rate() * 100:.0f}%), "
              f"{m.decode_steps / max(total, 1):.2f} verify steps/token")
    print("sample token ids:", completed[0].generated[:16])

    if telemetry is not None and telemetry.tracing:
        tr = telemetry.tracer
        n_spans = sum(1 for e in tr.events
                      if e["ph"] == "X" and e["name"] == "request")
        assert n_spans == len(completed), \
            f"trace has {n_spans} request spans, served {len(completed)}"
        if args.trace_out:
            tr.write_chrome(args.trace_out)
            with open(args.trace_out) as f:
                loaded = json.load(f)          # self-check: valid JSON
            print(f"trace: {len(loaded['traceEvents'])} events "
                  f"({n_spans} request spans) -> {args.trace_out} "
                  f"(open in chrome://tracing or ui.perfetto.dev)")
        if args.trace_jsonl:
            tr.write_jsonl(args.trace_jsonl)
            print(f"trace events (JSONL) -> {args.trace_jsonl}")
    if args.metrics_out:
        text = engine.render_prom()
        parse_prom(text)                       # self-check: parseable
        with open(args.metrics_out, "w") as f:
            f.write(text)
        print(f"metrics: {len(text.splitlines())} lines of Prometheus "
              f"text exposition -> {args.metrics_out}")


if __name__ == "__main__":
    main()
