"""Serving driver: batched prefill + decode loop with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgs
from repro.dist import sharding as shd
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.launch.train import tiny_config
from repro.models.api import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=cfgs.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--scale", choices=["tiny", "full"], default="tiny")
    args = ap.parse_args()

    cfg = tiny_config(args.arch) if args.scale == "tiny" \
        else cfgs.get_config(args.arch)
    mesh = make_host_mesh()
    api = get_model(cfg)
    max_len = args.prompt_len + args.gen + \
        (cfg.num_vision_tokens if cfg.family == "vlm" else 0)

    with shd.use_mesh(mesh):
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        cache = api.init_cache(cfg, args.batch, max_len)
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

        extra = []
        offset = args.prompt_len
        if cfg.family == "vlm":
            extra = [jnp.zeros((args.batch, cfg.num_vision_tokens,
                                cfg.d_model), cfg.jnp_dtype)]
            offset += cfg.num_vision_tokens
        if cfg.family == "audio":
            extra = [jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                               cfg.jnp_dtype)]

        t0 = time.monotonic()
        if cfg.family == "vlm":
            logits, cache = api.prefill(cfg, params, tokens, cache,
                                        vision_embeds=extra[0])
        elif cfg.family == "audio":
            logits, cache = api.prefill(cfg, params, tokens, cache, extra[0])
        else:
            logits, cache = api.prefill(cfg, params, tokens, cache)
        t_prefill = time.monotonic() - t0

        decode = jax.jit(lambda p, c, t, q: api.decode_step(cfg, p, c, t, q))
        out_tokens = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.monotonic()
        for i in range(args.gen):
            out_tokens.append(np.asarray(tok))
            logits, cache = decode(params, cache, tok, jnp.int32(offset + i))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(logits)
        t_decode = time.monotonic() - t0

        gen = np.concatenate(out_tokens, axis=1)
        assert np.isfinite(np.asarray(logits)).all()
        print(f"prefill: {t_prefill:.2f}s for {args.batch}x{args.prompt_len}")
        print(f"decode : {t_decode / args.gen * 1000:.1f} ms/token "
              f"(batch {args.batch})")
        print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
