"""Training driver: data pipeline -> supervised jit step -> checkpoints.

Runs on whatever devices exist (CPU in this container; the production mesh
on a real pod).  End-to-end example driver for deliverable (b):

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
      --steps 200 --batch 8 --seq 256 --scale tiny --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import base as cfgs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.dist import sharding as shd
from repro.dist.fault import FaultConfig, Supervisor
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import OptConfig

TINY_OVERRIDES = dict(
    num_layers=2, scan_repeats=2, prefix_kinds=(), suffix_kinds=(),
    d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
    vocab_size=512, dtype="float32", window=64,
)


def tiny_config(arch: str):
    cfg = cfgs.get_config(arch)
    over = dict(TINY_OVERRIDES)
    if cfg.family == "ssm":
        over.update(num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
                    ssm_heads=4, ssm_state=16, ssm_chunk=32, expand=2)
    if cfg.family == "moe":
        over.update(num_experts=4, top_k=2, moe_d_ff=128,
                    num_shared_experts=min(1, cfg.num_shared_experts))
        if cfg.prefix_kinds:
            over.update(prefix_kinds=cfg.prefix_kinds[:1], scan_repeats=1,
                        num_layers=2)
        if cfg.kv_lora_rank:
            over.update(num_kv_heads=4, kv_lora_rank=32, q_lora_rank=48,
                        rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
    if cfg.family == "hybrid":
        over.update(scan_repeats=1, suffix_kinds=("rglru",), num_layers=4,
                    lru_width=128, num_kv_heads=1)
    if cfg.family == "vlm":
        over.update(num_vision_tokens=8, num_kv_heads=1)
    if cfg.family == "audio":
        over.update(encoder_layers=2, encoder_seq=32, num_kv_heads=4)
    if cfg.scan_pattern and len(cfg.scan_pattern) > 1:
        over.update(scan_repeats=max(1, over["num_layers"]
                                     // len(cfg.scan_pattern)))
        over["num_layers"] = over["scan_repeats"] * len(cfg.scan_pattern) \
            + len(over.get("suffix_kinds", ()))
    return cfg.scaled(**over)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=cfgs.ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--scale", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = tiny_config(args.arch) if args.scale == "tiny" \
        else cfgs.get_config(args.arch)
    mesh = make_host_mesh()
    oc = OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    with shd.use_mesh(mesh):
        step_fn, state_shardings = steps_mod.build_train_step(cfg, mesh, oc)
        state = steps_mod.init_train_state(cfg, mesh, jax.random.PRNGKey(0))

        sup = Supervisor(FaultConfig(ckpt_dir=args.ckpt_dir,
                                     ckpt_every=args.ckpt_every))
        state, start = sup.maybe_restore(state)

        data = SyntheticLM(cfg.vocab_size, args.batch, args.seq)
        pf = Prefetcher(data, start_step=start)
        losses = []
        t0 = time.monotonic()
        for step in range(start, args.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in next(pf).items()}
            if cfg.family == "vlm":
                batch["vision_embeds"] = jax.numpy.zeros(
                    (args.batch, cfg.num_vision_tokens, cfg.d_model),
                    cfg.jnp_dtype)
            if cfg.family == "audio":
                batch["frame_embeds"] = jax.numpy.zeros(
                    (args.batch, cfg.encoder_seq, cfg.d_model),
                    cfg.jnp_dtype)
            state, report = sup.run_step(step_fn, state, batch, step)
            losses.append(report.loss)
            sup.maybe_save(state, step)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.monotonic() - t0
                print(f"step {step:5d} loss {report.loss:8.4f} "
                      f"({dt / max(step - start + 1, 1):.2f}s/step)",
                      flush=True)
        pf.close()
        sup.finalize(state, args.steps)
        head = float(np.mean(losses[:10]))
        tail = float(np.mean(losses[-10:]))
        print(json.dumps({"first10_loss": head, "last10_loss": tail,
                          "events": sup.events[-5:]}))
        if args.steps >= 100:
            assert tail < head, "training did not reduce loss"


if __name__ == "__main__":
    main()
