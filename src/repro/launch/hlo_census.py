"""Trip-weighted census of scheduled HLO: FLOPs, HBM traffic, collectives.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` (lax.scan)
body ONCE regardless of trip count (verified experimentally — a x8 scanned
matmul reports 1x flops).  Every model here scans over layers, so all
roofline terms must be re-derived with loop weighting:

  * parse the scheduled HLO into computations;
  * build the call graph (while/fusion/call/conditional edges), weighting
    while bodies by their trip count (largest constant compared in the
    loop condition — all our loops are counted scans);
  * FLOPs: every ``dot`` op contributes 2 * prod(result_shape) * K, with K
    looked up from the lhs operand's shape via a module-wide symbol table
    (operands are bare %names in scheduled HLO). Convolutions contribute
    2 * prod(result) * prod(kernel_spatial) * Cin.
  * HBM bytes: for ops at kernel granularity (i.e. in non-fusion execution
    contexts), result bytes + operand bytes, skipping pure-aliasing ops
    (bitcast, tuple, get-tuple-element, parameter).  This approximates each
    scheduled top-level op as one kernel — the same granularity XLA's own
    cost model uses on the scheduled module.
  * collectives: operand bytes per op kind (see dryrun._line_collective).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3|s64|s32|s16|s8|u64|u32|"
                       r"u16|u8|pred|c64|c128)\[([\d,]*)\]")

_ALIAS_OPS = {"bitcast", "tuple", "get-tuple-element", "parameter",
              "constant", "after-all", "iota"}

# ops a TPU fusion absorbs into its producer/consumer — counting their
# operands+results as HBM traffic models XLA:CPU's (non-)fusion, not the
# TPU target.  hbm_bytes(mode="tpu") skips them.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "negate", "abs",
    "and", "or", "xor", "not", "select", "compare", "convert", "rsqrt",
    "sqrt", "power", "sine", "cosine", "log", "log-plus-one", "clamp",
    "floor", "ceil", "round-nearest-even", "round-nearest-afz", "sign",
    "is-finite", "reduce-precision", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "population-count", "remainder", "atan2",
    "expm1", "log1p", "copy", "pad", "reverse", "concatenate",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(m: re.Match) -> tuple[int, ...]:
    return tuple(int(d) for d in m.group(2).split(",") if d)


def _bytes_of(m: re.Match) -> int:
    n = 1
    for d in _dims(m):
        n *= d
    return n * _DTYPE_BYTES[m.group(1)]


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    line: str
    result_shapes: list[re.Match]
    operands: list[str]


def _parse_instruction(line: str) -> Instruction | None:
    ls = line.strip()
    m = re.match(r"(?:ROOT )?%?([\w.\-]+) = (.*)$", ls)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    om = re.search(r"\b([a-z][a-z0-9\-]*)\(", rest)
    if not om:
        return None
    op = om.group(1)
    head = rest[:om.start()]
    result_shapes = list(_SHAPE_RE.finditer(head))
    args = rest[om.end():]
    depth = 1
    end = 0
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = re.findall(r"%([\w.\-]+)", args[:end])
    return Instruction(name, op, ls, result_shapes, operands)


class HloCensus:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instruction]] = {}
        self.symbols: dict[str, list[re.Match]] = {}
        cur = None
        for line in hlo_text.splitlines():
            is_header = (line and not line[0].isspace() and "->" in line
                         and line.rstrip().endswith("{"))
            if is_header:
                hm = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
                cur = hm.group(1) if hm else None
                if cur:
                    self.comps[cur] = []
                continue
            if cur is None:
                continue
            if line.startswith("}"):
                cur = None
                continue
            inst = _parse_instruction(line)
            if inst:
                self.comps[cur].append(inst)
                self.symbols[inst.name] = inst.result_shapes
        self._weights = self._compute_weights()

    # -- call graph / loop weights ------------------------------------------
    def _trip_count(self, cond: str) -> int:
        best = 1
        for inst in self.comps.get(cond, []):
            for m in re.finditer(r"constant\((\d+)\)", inst.line):
                best = max(best, int(m.group(1)))
        return best

    def _edges(self, inst: Instruction) -> list[tuple[str, int]]:
        """(callee computation, weight multiplier) pairs of one instruction."""
        edges: list[tuple[str, int]] = []
        bm = re.search(r"body=%?([\w.\-]+)", inst.line)
        if bm:
            trip = 1
            km = re.search(r'known_trip_count[^}]*"n":"(\d+)"', inst.line)
            if km:
                trip = int(km.group(1))
            else:
                cm = re.search(r"condition=%?([\w.\-]+)", inst.line)
                if cm:
                    trip = self._trip_count(cm.group(1))
            edges.append((bm.group(1), trip))
        cm = re.search(r"condition=%?([\w.\-]+)", inst.line)
        if cm:
            edges.append((cm.group(1), 1))
        for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", inst.line):
            edges.append((m.group(1), 1))
        bm = re.search(r"branch_computations=\{([^}]*)\}", inst.line)
        if bm:
            for t in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                edges.append((t, 1))
        return edges

    def _compute_weights(self) -> dict[str, int]:
        entry = next((n for n in self.comps if n.startswith("main")
                      or "entry" in n.lower()), None)
        if entry is None:  # fall back: computation with most instructions
            entry = max(self.comps, key=lambda n: len(self.comps[n]))
        weights = {entry: 1}
        changed = True
        guard = 0
        while changed and guard < 50:
            changed = False
            guard += 1
            for cname, insts in self.comps.items():
                w = weights.get(cname)
                if w is None:
                    continue
                for inst in insts:
                    for target, mult in self._edges(inst):
                        if target in self.comps:
                            nw = w * mult
                            if weights.get(target, 0) < nw:
                                weights[target] = nw
                                changed = True
        return weights

    def _op_k(self, inst: Instruction) -> int:
        """contraction size of a dot from its lhs operand + contracting dims"""
        cm = re.search(r"lhs_contracting_dims=\{([\d,]+)\}", inst.line)
        if not cm or not inst.operands:
            return 0
        lhs = self.symbols.get(inst.operands[0])
        if not lhs:
            return 0
        dims = _dims(lhs[0])
        k = 1
        for d in cm.group(1).split(","):
            if int(d) < len(dims):
                k *= dims[int(d)]
        return k

    # -- public counts --------------------------------------------------------
    def flops(self) -> float:
        total = 0.0
        for cname, insts in self.comps.items():
            w = self._weights.get(cname, 0)
            if not w:
                continue
            for inst in insts:
                if inst.op == "dot" and inst.result_shapes:
                    out = 1
                    for d in _dims(inst.result_shapes[0]):
                        out *= d
                    total += w * 2.0 * out * max(self._op_k(inst), 1)
                elif inst.op == "convolution" and inst.result_shapes:
                    out = 1
                    for d in _dims(inst.result_shapes[0]):
                        out *= d
                    wm = re.search(r"window=\{size=([\dx]+)", inst.line)
                    ksz = 1
                    if wm:
                        for d in wm.group(1).split("x"):
                            ksz *= int(d)
                    cin = 1
                    if inst.operands and len(inst.operands) > 1:
                        rhs = self.symbols.get(inst.operands[1])
                        if rhs:
                            rd = _dims(rhs[0])
                            cin = rd[-2] if len(rd) >= 2 else 1
                    total += w * 2.0 * out * ksz * cin
        return total

    def hbm_bytes(self, mode: str = "tpu") -> float:
        """Approximate HBM traffic: result+operand bytes of every scheduled
        top-level op (fusion internals excluded — the fusion op itself is
        the kernel).

        mode="cpu": every scheduled op is a kernel (XLA:CPU granularity —
        an upper bound).  mode="tpu" (default): elementwise/layout ops are
        assumed fused into their consumers, approximating the TPU target's
        fusion behaviour; dots/reduces/data-movement still count.

        Ops that touch only a window of their operands are modelled by the
        window, not the full buffer: dynamic-slice/gather read ~result-sized
        data; dynamic-update-slice writes ~update-sized data; while/call/
        conditional are control flow (their bodies are counted separately);
        the loop-carried tuple is NOT re-counted per iteration.
        """
        fusion_comps = set()
        for insts in self.comps.values():
            for inst in insts:
                if inst.op == "fusion":
                    for m in re.finditer(r"calls=%?([\w.\-]+)", inst.line):
                        fusion_comps.add(m.group(1))
        skip = _ALIAS_OPS | {"while", "call", "conditional", "custom-call"}
        if mode == "tpu":
            skip = skip | _ELEMENTWISE
        total = 0.0
        for cname, insts in self.comps.items():
            w = self._weights.get(cname, 0)
            if not w or cname in fusion_comps:
                continue
            for inst in insts:
                if inst.op in skip:
                    continue
                res = sum(_bytes_of(m) for m in inst.result_shapes)
                if inst.op in ("dynamic-slice", "gather", "broadcast",
                               "reshape", "slice"):
                    nbytes = 2 * res              # read window + write result
                elif inst.op == "dynamic-update-slice":
                    upd = 0
                    if len(inst.operands) > 1:
                        shapes = self.symbols.get(inst.operands[1])
                        if shapes:
                            upd = sum(_bytes_of(m) for m in shapes)
                    nbytes = 2 * upd              # read update + write window
                elif inst.op in ("scatter", "scatter-add"):
                    upd = 0
                    if len(inst.operands) > 2:
                        shapes = self.symbols.get(inst.operands[2])
                        if shapes:
                            upd = sum(_bytes_of(m) for m in shapes)
                    nbytes = 2 * upd
                else:
                    nbytes = res
                    for o in inst.operands:
                        shapes = self.symbols.get(o)
                        if shapes:
                            nbytes += sum(_bytes_of(m) for m in shapes)
                total += w * nbytes
        return total

    def collective_bytes(self) -> dict[str, float]:
        out = {k: 0.0 for k in _COLLECTIVES}
        out["count"] = 0
        for cname, insts in self.comps.items():
            w = self._weights.get(cname, 0)
            if not w:
                continue
            for inst in insts:
                kind = inst.op.replace("-start", "")
                if kind not in _COLLECTIVES:
                    continue
                nbytes = sum(_bytes_of(m) for m in inst.result_shapes)
                if kind == "all-gather":
                    nbytes //= max(self._group_size(inst.line), 1)
                elif kind == "reduce-scatter":
                    nbytes *= max(self._group_size(inst.line), 1)
                out[kind] += w * nbytes
                out["count"] += 1
        out["total"] = sum(out[k] for k in _COLLECTIVES)
        return out

    @staticmethod
    def _group_size(line: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
        if m:
            return len(m.group(1).split(","))
        return 1
