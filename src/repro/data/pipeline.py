"""Deterministic synthetic data pipelines, host-sharded, with prefetch.

Real-cluster shape: every host generates only its slice of the global batch
(``host_id``/``num_hosts``), the loader is a background-thread prefetcher,
and every batch is reproducible from (seed, step) alone — restart-safe by
construction (checkpoint stores the step; the pipeline needs no state).

The LM stream is a learnable synthetic language: labels are an affine
permutation of the token (plus a context-mix term), so cross-entropy has a
clean floor and "loss decreases" tests are meaningful.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        assert global_batch % num_hosts == 0
        self.vocab = vocab
        self.local_batch = global_batch // num_hosts
        self.seq = seq_len
        self.seed = seed
        self.host = host_id

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(
            key=[(self.seed << 20) ^ self.host, (step << 4) ^ 0xB]))
        tok = rng.integers(0, self.vocab, size=(self.local_batch, self.seq),
                           dtype=np.int64)
        # learnable map: label_t = (a * tok_t + b + tok_{t-1}) % V
        prev = np.roll(tok, 1, axis=1)
        prev[:, 0] = 0
        labels = (5 * tok + 3 + prev) % self.vocab
        return {"tokens": tok.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class SyntheticImages:
    """Class-conditional Gaussian blobs -> learnable image classification."""

    def __init__(self, num_classes: int, image_size: int, global_batch: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        self.nc = num_classes
        self.sz = image_size
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host = host_id
        rng = np.random.Generator(np.random.Philox(key=[seed, 1]))
        self.means = rng.standard_normal((num_classes, 8)).astype(np.float32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(
            key=[(self.seed << 20) ^ self.host, (step << 4) ^ 0xF]))
        labels = rng.integers(0, self.nc, size=(self.local_batch,))
        base = self.means[labels]                       # (B, 8)
        grid = np.linspace(-1, 1, self.sz, dtype=np.float32)
        gx, gy = np.meshgrid(grid, grid)
        feats = np.stack([gx, gy, gx * gy, gx ** 2, gy ** 2,
                          np.sin(3 * gx), np.cos(3 * gy),
                          np.ones_like(gx)], -1)        # (H, W, 8)
        img = np.einsum("bf,hwf->bhw", base, feats)[..., None]
        img = np.repeat(img, 3, axis=-1)
        img += 0.3 * rng.standard_normal(img.shape).astype(np.float32)
        return {"images": img.astype(np.float32),
                "labels": labels.astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch (depth-k) over a step-indexed source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
