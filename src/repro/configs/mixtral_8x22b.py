"""mixtral-8x22b [moe] — 8 experts top-2, SWA (arXiv:2401.04088; hf
mistralai/Mixtral-8x22B).

56L d_model=6144 48H (GQA kv=8) head_dim=128, expert d_ff=16384
vocab=32768, MoE 8e top-2, sliding window 4096 (mixtral-v0.1 style SWA
per the assignment).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,               # per-expert ffn dim
    vocab_size=32_768,
    scan_pattern=("swa_moe",),
    scan_repeats=56,
    window=4096,
    num_experts=8,
    top_k=2,
    moe_d_ff=16384,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
