"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention
(arXiv:2401.16818; hf h2oai/h2o-danube-1.8b).

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, head_dim=80,
SWA window 4096 (mistral-style) on every layer.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32_000,
    scan_pattern=("swa",),
    scan_repeats=24,
    window=4096,
    mlp_act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
