"""gemma2-2b [dense] — local/global alternating attention, logit softcaps
(arXiv:2408.00118; hf google/gemma-2-2b).

26L d_model=2304 8H (GQA kv=4) head_dim=256 d_ff=9216 vocab=256000,
window 4096 on local layers, attn softcap 50, final softcap 30,
GeGLU, sandwich norms, sqrt(d_model) embedding scale.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    scan_pattern=("local", "global"),
    scan_repeats=13,
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="geglu",
    post_norms=True,
    scale_embeddings=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
