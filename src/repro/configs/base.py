"""Config system: model/arch configs, input shapes, registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves it.  Input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig`` and
``input_specs`` builds the ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer-stack structure: prefix + pattern * repeats + suffix
    scan_pattern: tuple[str, ...] = ("attn",)
    scan_repeats: int = 0
    prefix_kinds: tuple[str, ...] = ()
    suffix_kinds: tuple[str, ...] = ()

    # attention variants
    window: int = 0                   # sliding/local window size
    attn_logit_softcap: float = 0.0   # gemma2
    final_logit_softcap: float = 0.0  # gemma2
    rope_theta: float = 10_000.0
    post_norms: bool = False          # gemma2 sandwich norms
    mlp_act: str = "swiglu"           # swiglu | geglu | gelu

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    conv_kernel: int = 4
    expand: int = 2
    ssm_groups: int = 1

    # hybrid (recurrentgemma)
    lru_width: int = 0

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0              # frames after the (stubbed) conv frontend

    # vlm (paligemma)
    num_vision_tokens: int = 0

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embeddings: bool = False    # gemma-family sqrt(d_model) scaling
    remat: bool = True                # activation checkpointing on scan blocks
    remat_policy: str = "full"        # full | dots.  §Perf iter G7: "dots"
                                      # (save weight-stationary dot outputs)
                                      # cuts recompute FLOPs 17% but grows
                                      # live memory 7.9->19.2 GB/device —
                                      # wrong trade for these memory-bound
                                      # cells; kept selectable for compute-
                                      # bound configs.
    dtype: str = "bfloat16"

    # paper-technique integration switches (BNN mode; see DESIGN.md §5)
    binarize_mlp: bool = False
    compress_weights: bool = False

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        kinds = (self.prefix_kinds
                 + self.scan_pattern * self.scan_repeats
                 + self.suffix_kinds)
        # decoder-side kinds only; encoder layers (whisper) live in encdec.py
        assert len(kinds) == self.num_layers, (self.name, len(kinds))
        return kinds

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k is runnable (sub-quadratic; DESIGN.md §5)
LONG_CONTEXT_OK = frozenset({
    "mamba2-780m", "recurrentgemma-2b", "h2o-danube-1.8b",
    "gemma2-2b", "mixtral-8x22b",
})

ARCH_NAMES = (
    "mamba2-780m", "gemma2-2b", "minitron-8b", "phi3-medium-14b",
    "h2o-danube-1.8b", "mixtral-8x22b", "deepseek-v2-236b",
    "recurrentgemma-2b", "paligemma-3b", "whisper-large-v3",
)

_MODULE_OF = {name: name.replace("-", "_").replace(".", "_")
              for name in ARCH_NAMES}
_MODULE_OF["reactnet"] = "reactnet"


def get_config(name: str) -> Any:
    """Resolve an arch name to its config object (ModelConfig or BNN config)."""
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[name]}")
    return mod.CONFIG


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    """Whether the (arch x shape) dry-run cell runs, and why not if skipped."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "skip(full-attn)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation (dry-run contract).
    """
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.jnp_dtype
    i32 = jnp.int32

    def st(shp, dtype):
        return jax.ShapeDtypeStruct(shp, dtype)

    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = st((b, s), i32)
        specs["labels"] = st((b, s), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = st((b, s), i32)
    else:  # decode: one new token against a KV cache of length s
        specs["tokens"] = st((b, 1), i32)
        specs["pos"] = st((), i32)

    if cfg.family == "vlm":
        specs["vision_embeds"] = st((b, cfg.num_vision_tokens, cfg.d_model), dt)
    if cfg.family == "audio":
        # stubbed conv frontend: precomputed frame embeddings
        specs["frame_embeds"] = st((b, cfg.encoder_seq, cfg.d_model), dt)
    return specs
