"""mamba2-780m [ssm] — SSD state-space duality (arXiv:2405.21060).

48L d_model=1536, attention-free (d_ff=0), vocab 50280, ssm_state=128.
Mamba2 defaults: expand=2 (d_inner=3072), head_dim=64 -> 48 SSD heads,
ngroups=1, conv kernel 4, chunk 256.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    scan_pattern=("ssm",),
    scan_repeats=48,
    ssm_state=128,
    ssm_heads=48,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_kernel=4,
    expand=2,
    ssm_groups=1,
    tie_embeddings=True,
)
