"""minitron-8b [dense] — pruned Nemotron-4 (arXiv:2407.14679; hf
nvidia/Minitron-8B-Base).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000, head_dim=128,
squared-ReLU MLP in Nemotron; we use the substrate's gated form with the
published dims (systems-equivalent FLOP shape).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256_000,
    scan_pattern=("attn",),
    scan_repeats=32,
    mlp_act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
