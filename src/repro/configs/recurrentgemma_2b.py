"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern
(arXiv:2402.19427; hf google/recurrentgemma-2b).

26L d_model=2560 10H (GQA kv=1) head_dim=256 d_ff=7680 (GeGLU),
lru_width=2560, local attention window 2048, vocab 256000.
Pattern (rec, rec, attn) x 8 + (rec, rec) = 26 layers.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    scan_pattern=("rglru", "rglru", "attn_local"),
    scan_repeats=8,
    suffix_kinds=("rglru", "rglru"),
    window=2048,
    lru_width=2560,
    mlp_act="geglu",
    scale_embeddings=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
