"""ReActNet-A (the paper's own model) — see repro.models.reactnet."""

from repro.models.reactnet import CONFIG  # noqa: F401
