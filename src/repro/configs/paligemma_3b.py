"""paligemma-3b [vlm] — SigLIP + gemma backbone (arXiv:2407.07726; hf
google/paligemma-3b).

LM backbone only per the brief: 18L d_model=2048 8H (GQA kv=1)
head_dim=256 d_ff=16384 vocab=257216.  The SigLIP frontend is a STUB —
input_specs provides 256 precomputed patch embeddings (224px / patch 14),
prepended as a bidirectional prefix (prefix-LM masking).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    scan_pattern=("attn",),
    scan_repeats=18,
    num_vision_tokens=256,
    mlp_act="geglu",
    scale_embeddings=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
