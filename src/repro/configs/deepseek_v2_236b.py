"""deepseek-v2-236b [moe] — MLA + shared/routed MoE (arXiv:2405.04434; hf
deepseek-ai/DeepSeek-V2).

60L d_model=5120 128H, MLA kv_lora_rank=512 q_lora_rank=1536,
nope/v head_dim 128, rope head_dim 64; MoE: 2 shared + 160 routed experts,
top-6, expert d_ff=1536; vocab 102400.  First layer uses a dense MLP
(d_ff = 12288) per the released config.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=12288,               # dense-MLP dim (layer 0)
    vocab_size=102_400,
    prefix_kinds=("mla_dense",),
    scan_pattern=("mla_moe",),
    scan_repeats=59,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    mlp_act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
