"""whisper-large-v3 [audio] — enc-dec backbone, conv frontend stubbed
(arXiv:2212.04356).

32 encoder + 32 decoder layers, d_model=1280 20H (kv=20, MHA) head_dim=64,
d_ff=5120, vocab 51866.  The mel/conv frontend is a STUB: input_specs
provides precomputed frame embeddings; encoder length 1536 (1500 native
frames padded to the attention chunk grid, DESIGN.md §7).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq=1536,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    scan_pattern=("dec",),
    scan_repeats=32,
    mlp_act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
