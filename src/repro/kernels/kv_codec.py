"""KV-page codec: codebook quantization + Huffman archive for paged KV.

The paper compresses binary-weight kernels by exploiting a skewed
bit-sequence distribution: frequent sequences get short Huffman codes
and are decoded through a tiny cache (PAPER SectionIII-IV).  At serving
time the paged KV pool is the activation-side analogue — every slot's
K/V pages pay full fp bytes per token even though per-token value
distributions are heavily concentrated around zero.

This module is the single source of truth for the ``kv_codec`` seam:

* ``"none"``   — pages stay in the model dtype; bit-exact oracle.
* ``"cluster"``— page contents are clustered onto a 256-entry codebook
  (symmetric int8 levels) with one f32 scale per (slot, token); pages
  are stored as int8 codes at rest and decoded *in-kernel* by
  ``kernels.paged_attention`` (codebook lookup in VMEM after the
  per-page DMA, before the online-softmax score) — the same shape as
  ``kernels.fused_decode_contraction``'s weight-tile decode.

On top of the resident int8 pool, :func:`huffman_report` /
:func:`archive_pages` reuse ``core.huffman`` + ``core.clustering`` to
measure and build the at-rest Huffman stream for cold pages (codes live
in the same <512-symbol space the paper's coder was built for).

Design constraints the codec satisfies:

* codebook[ZERO] == 0 exactly, so all-zero pages (the page-0 dummy
  sink) encode to code 0 / scale 0 and decode back to exactly zero.
* encode∘decode is idempotent: the amax element maps to ±MAX_CODE, so
  re-encoding a decoded page recovers the same scale and codes.  The
  gathered backend relies on this — it re-encodes whole views on every
  scatter.
* reconstruction error is elementwise-bounded by ``scale / 254``
  (half a quantization step of the per-token scale).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

KV_CODECS = ("none", "cluster")

LEVELS = 256            # codebook entries == int8 code space
ZERO_CODE = LEVELS // 2  # codebook index of code 0 (decodes to exactly 0.0)
MAX_CODE = LEVELS // 2 - 1  # 127: symmetric clip range for codes


def codebook() -> jnp.ndarray:
    """``(LEVELS,)`` f32 centroids in units of the per-token scale.

    ``codebook()[code + ZERO_CODE] == code / MAX_CODE`` for int8
    ``code`` in ``[-MAX_CODE, MAX_CODE]``; entry ``ZERO_CODE`` is 0.0,
    so zero codes decode to zero regardless of scale.
    """
    return (jnp.arange(LEVELS, dtype=jnp.float32) - ZERO_CODE) / MAX_CODE


def encode(values, axes):
    """Quantize ``values`` onto the codebook.

    ``axes`` are the feature axes reduced into one amax scale per
    remaining (slot, token) index.  Returns ``(codes, scale)`` where
    ``codes`` is int8 with ``values.shape`` and ``scale`` is f32 with
    ``axes`` squeezed out.  All-zero tokens get scale 0 and code 0.
    """
    v = jnp.asarray(values, jnp.float32)
    axes = tuple(ax % v.ndim for ax in axes)
    scale = jnp.max(jnp.abs(v), axis=axes, keepdims=True)
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(v / safe * MAX_CODE), -MAX_CODE, MAX_CODE)
    return codes.astype(jnp.int8), jnp.squeeze(scale, axis=axes)


def decode(codes, scale):
    """Inverse of :func:`encode`: ``codebook[codes + ZERO_CODE] * scale``.

    ``scale`` must already broadcast against ``codes`` (callers expand
    the squeezed feature axes back).
    """
    vals = codebook()[jnp.asarray(codes, jnp.int32) + ZERO_CODE]
    return vals * jnp.asarray(scale, jnp.float32)


def error_bound(scale):
    """Elementwise bound: ``|decode(encode(v)) - v| <= scale / (2*MAX_CODE)``."""
    return jnp.asarray(scale, jnp.float32) / (2 * MAX_CODE)


# ---------------------------------------------------------------------------
# At-rest Huffman layer (host-side, exact) — reuses the paper's coder.
# ---------------------------------------------------------------------------

def huffman_report(codes) -> dict:
    """Entropy report of an int8 code pool through the paper's coder.

    Histograms ``codes + ZERO_CODE`` (all < 512, i.e. inside the
    ``core.bitpack`` sequence space), assigns node-limited Huffman
    codes, and also measures what Hamming-1 clustering
    (``core.clustering.apply_clustering``) would add.  The clustered
    ratio is a *report only* — the resident pool keeps raw int8 codes;
    only the exact (non-clustered) stream is used by
    :func:`archive_pages`.
    """
    from repro.core.bitpack import NUM_SEQUENCES
    from repro.core.clustering import apply_clustering
    from repro.core.huffman import assign_nodes

    flat = np.asarray(codes).ravel().astype(np.int64) + ZERO_CODE
    hist = np.bincount(flat, minlength=NUM_SEQUENCES).astype(np.int64)
    assign = assign_nodes(hist)
    avg = assign.avg_bits(hist)
    clustered, _ = apply_clustering(flat, hist=hist)
    chist = np.bincount(np.asarray(clustered, np.int64),
                        minlength=NUM_SEQUENCES).astype(np.int64)
    cavg = assign_nodes(chist).avg_bits(chist)
    return {
        "symbols": int(flat.size),
        "avg_bits": float(avg),
        "ratio": (8.0 / avg) if avg else float("inf"),
        "clustered_avg_bits": float(cavg),
        "clustered_ratio": (8.0 / cavg) if cavg else float("inf"),
    }


def archive_pages(codes):
    """Huffman-encode int8 codes into an exact uint32 bit stream.

    Returns ``(words, nbits, assign)`` suitable for
    :func:`restore_pages`; the stream is lossless (no clustering).
    """
    from repro.core.bitpack import NUM_SEQUENCES
    from repro.core.huffman import assign_nodes, encode_stream

    flat = np.asarray(codes).ravel().astype(np.int64) + ZERO_CODE
    hist = np.bincount(flat, minlength=NUM_SEQUENCES).astype(np.int64)
    assign = assign_nodes(hist)
    words, nbits = encode_stream(flat, assign)
    return words, nbits, assign


def restore_pages(words, nbits, assign, shape):
    """Exact inverse of :func:`archive_pages` back to int8 codes."""
    from repro.core.huffman import decode_stream

    seqs = decode_stream(words, nbits, assign,
                         count=int(np.prod(shape)) if shape else 1)
    return (np.asarray(seqs, np.int64) - ZERO_CODE).astype(np.int8) \
        .reshape(shape)
