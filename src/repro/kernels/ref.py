"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are allclose-tested against
(tests/test_kernels.py sweeps shapes & dtypes).  They are also the CPU
fallback path used by the models when ``use_pallas=False``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitpack import BLOCK_K, SEQ_BITS, SEQS_PER_BLOCK

# decode-table geometry (mirrors repro.core.huffman)
_NODE_BASE = (0, 32, 96)        # flat offsets of node tables 0/1/2
_TABLE_SIZE = 160


# ---------------------------------------------------------------------------
# packing (runtime jnp mirror of bitpack.pack_gemm_operand)
# ---------------------------------------------------------------------------

def pack_bits_runtime(bits: jax.Array) -> jax.Array:
    """(M, K) {0,1} -> (M, G, 9) uint32 sequence-aligned packed words.

    K is zero-padded (-1s) to a whole number of 288-bit blocks;
    :func:`popcount_dot` corrects for the padding.
    """
    m, k = bits.shape
    kp = -(-k // BLOCK_K) * BLOCK_K
    bits = jnp.pad(bits.astype(jnp.uint32), ((0, 0), (0, kp - k)))
    blocks = bits.reshape(m, kp // BLOCK_K, SEQS_PER_BLOCK, SEQ_BITS)
    blocks = jnp.swapaxes(blocks, -1, -2)            # (M, G, 9, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)        # bit i = sequence i
    return (blocks << shifts).sum(-1, dtype=jnp.uint32)


def binarize_pack(x: jax.Array) -> jax.Array:
    """(M, K) real -> packed sign bits (1 <-> x >= 0)."""
    return pack_bits_runtime((x >= 0).astype(jnp.uint32))


def pack_sequences(seqs: jax.Array) -> jax.Array:
    """(N, G) int sequences -> (N, G, 9) uint32 packed words.

    Inverse-free repack used after Huffman decode: word j of block g packs bit
    j (MSB-first: bit 8-j of the 9-bit value) of 32 consecutive sequences.
    G must be a multiple of 32.
    """
    n, g = seqs.shape
    assert g % SEQS_PER_BLOCK == 0, g
    s = seqs.astype(jnp.uint32).reshape(n, g // SEQS_PER_BLOCK, SEQS_PER_BLOCK)
    taps = jnp.arange(SEQ_BITS, dtype=jnp.uint32)    # j: tap index, MSB first
    bits = (s[:, :, None, :] >> (SEQ_BITS - 1 - taps)[None, None, :, None]) & 1
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (bits << shifts).sum(-1, dtype=jnp.uint32)   # (N, G', 9)


# ---------------------------------------------------------------------------
# binary contraction (xnor + popcount GEMM)
# ---------------------------------------------------------------------------

def popcount_dot(x_words: jax.Array, w_words: jax.Array, k_true: int) -> jax.Array:
    """(M, G, 9) x (N, G, 9) packed words -> (M, N) int32 +-1 dot product.

    dot = 2 * true_matches - k_true, where padded positions (0 in both
    operands) are subtracted from the raw xnor-popcount match count.
    """
    xw = x_words.reshape(x_words.shape[0], -1)
    ww = w_words.reshape(w_words.shape[0], -1)
    xnor = ~(xw[:, None, :] ^ ww[None, :, :])
    matches = jax.lax.population_count(xnor).sum(-1).astype(jnp.int32)
    n_pad = xw.shape[-1] * 32 - k_true
    return 2 * (matches - n_pad) - k_true


def binary_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference binary GEMM on real inputs: sign(x) @ sign(w).T  -> (M, N)."""
    xs = jnp.where(x >= 0, 1.0, -1.0)
    ws = jnp.where(w >= 0, 1.0, -1.0)
    return (xs @ ws.T).astype(jnp.float32)


def binary_conv3x3(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Reference BNN 3x3 conv, NHWC x (Cout, Cin, 3, 3), padding = -1 (SAME).

    Inputs are real; signs are taken inside (1 <-> >= 0).  Matches the packed
    pipeline in ops.binary_conv3x3.
    """
    xs = jnp.where(x >= 0, 1.0, -1.0)
    ws = jnp.where(w >= 0, 1.0, -1.0)
    xs = jnp.pad(xs, ((0, 0), (1, 1), (1, 1), (0, 0)), constant_values=-1.0)
    out = jax.lax.conv_general_dilated(
        xs, jnp.transpose(ws, (2, 3, 1, 0)),       # HWIO
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(jnp.float32)


# ---------------------------------------------------------------------------
# tiled Huffman decode (oracle for kernels/huffman_decode.py)
# ---------------------------------------------------------------------------

def decode_tile(words: jax.Array, tables_flat: jax.Array, c: int) -> jax.Array:
    """Decode one tile: (W, S) uint32 words -> (C, S) int32 sequences.

    Vectorised over the S substream lanes; the sequential chain is only the
    per-lane bit cursor (scan over C code steps).  Mirrors the simplified
    4-node coder: prefixes 0/10/110/111, code lengths 6/8/9/12, node 3 =
    escape (raw 9 bits).
    """
    w_rows, s = words.shape
    tables = tables_flat.astype(jnp.int32)

    def step(bitpos, _):
        word_idx = bitpos >> 5
        bit_off = bitpos & 31
        # one-hot gather of words[word_idx, lane] and the following word
        rows = jnp.arange(w_rows, dtype=jnp.int32)[:, None]
        w0 = jnp.sum(jnp.where(rows == word_idx[None, :], words, 0),
                     axis=0, dtype=jnp.uint32)
        nidx = jnp.minimum(word_idx + 1, w_rows - 1)
        w1 = jnp.sum(jnp.where(rows == nidx[None, :], words, 0),
                     axis=0, dtype=jnp.uint32)
        off = bit_off.astype(jnp.uint32)
        lo = jnp.where(off > 0, w1 >> (32 - jnp.maximum(off, 1)), 0)
        window = ((w0 << off) | lo) >> 20               # top 12 bits
        top3 = window >> 9
        is0 = top3 < 4
        is1 = (top3 >> 1) == 2
        is2 = top3 == 6
        is3 = top3 == 7
        flat_idx = jnp.where(
            is0, (window >> 6) & 31,
            jnp.where(is1, 32 + ((window >> 4) & 63), 96 + ((window >> 3) & 63)),
        ).astype(jnp.int32)
        # one-hot table gather (160 entries)
        tidx = jnp.arange(_TABLE_SIZE, dtype=jnp.int32)[:, None]
        tval = jnp.sum(jnp.where(tidx == flat_idx[None, :], tables[:, None], 0),
                       axis=0)
        val = jnp.where(is3, (window & 511).astype(jnp.int32), tval)
        length = jnp.where(is0, 6, jnp.where(is1, 8, jnp.where(is2, 9, 12)))
        return bitpos + length.astype(jnp.int32), val

    _, vals = jax.lax.scan(step, jnp.zeros(s, jnp.int32), None, length=c)
    return vals                                        # (C, S)


def decode_tiled(words: jax.Array, tables_flat: jax.Array, c: int) -> jax.Array:
    """(T, W, S) -> (T, C, S) int32 sequences (vmap over tiles)."""
    return jax.vmap(lambda wt: decode_tile(wt, tables_flat, c))(words)


def tiled_to_sequences(decoded: jax.Array, n_seqs: int) -> jax.Array:
    """(T, C, S) decode output -> flat (n_seqs,) in original order."""
    t, c, s = decoded.shape
    flat = decoded.reshape(t * c, s).reshape(-1)       # index = (t*C + c)*S + s
    return flat[:n_seqs]


def np_tables(assign) -> np.ndarray:
    """Convenience: NodeAssignment -> (160,) int32 flat decode tables."""
    return assign.decode_tables_flat()
