"""Pallas TPU kernel: xnor + popcount binary GEMM over packed words.

This is the uncompressed-weights baseline path (paper's daBnn analogue):
both operands are channel-packed uint32 words; the contraction is

    out[m, n] = 2 * (popcount(xnor(x[m, :], w[n, :])) - pad_bits) - k_true

Grid is (M/bm, N/bn, K/ck) with a VMEM int32 accumulator carried across the
innermost (arbitrary) K dimension; the +-1 correction is applied on the last
K step.  All VPU work — the MXU never sees the 1-bit operands, which is the
point: 32x fewer HBM/VMEM bytes per MAC than bf16.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_CK = 128   # uint32 words per K step (= 4096 binary MACs / output)


def _kernel(x_ref, w_ref, out_ref, acc_ref, *, nk: int, k_true: int,
            total_bits: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, ck) uint32
    w = w_ref[...]                                  # (bn, ck) uint32
    xnor = ~(x[:, None, :] ^ w[None, :, :])         # (bm, bn, ck)
    acc_ref[...] += jax.lax.population_count(xnor).sum(-1).astype(jnp.int32)

    @pl.when(kb == nk - 1)
    def _done():
        n_pad = total_bits - k_true
        out_ref[...] = 2 * (acc_ref[...] - n_pad) - k_true


@functools.partial(jax.jit, static_argnames=("k_true", "bm", "bn", "ck",
                                             "interpret"))
def binary_contraction(
    x_words: jax.Array,          # (M, KW) uint32  (flattened (G, 9))
    w_words: jax.Array,          # (N, KW) uint32
    *,
    k_true: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    ck: int = DEFAULT_CK,
    interpret: bool = False,
) -> jax.Array:
    m, kw = x_words.shape
    n, kw2 = w_words.shape
    assert kw == kw2, (kw, kw2)
    bm, bn, ck = min(bm, m), min(bn, n), min(ck, kw)
    # pad every dim to a block multiple (zero words are corrected as pad bits)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-kw // ck) * ck
    x_words = jnp.pad(x_words, ((0, mp - m), (0, kp - kw)))
    w_words = jnp.pad(w_words, ((0, np_ - n), (0, kp - kw)))
    nk = kp // ck
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, k_true=k_true, total_bits=kp * 32),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, ck), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bn, ck), lambda mi, ni, ki: (ni, ki)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_words, w_words)
    return out[:m, :n]
