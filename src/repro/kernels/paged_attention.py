"""Pallas TPU kernel: in-kernel paged decode attention.

Decode attention that consumes the scheduler's paged KV layout *directly*:
the physical page pool ``(n_pages, page, KH, D)`` plus a per-slot page
table and per-slot lengths.  Each ``(slot, logical page)`` grid step pulls
exactly one physical page into VMEM — the BlockSpec index map reads the
page table through scalar prefetch, so the DMA engine walks the table and
never touches pages the slot does not own — applies the absolute-position
mask, and folds the page into an online-softmax accumulator held in VMEM
scratch.  No contiguous per-slot view of the cache is ever materialised,
in HBM or anywhere else: this is the serving analogue of the paper's
in-pipeline decoding unit (§IV), which consumes operands in their at-rest
layout instead of expanding them into memory first.

Layout contract (shared with ``runtime.scheduler.SlotPool``):

  * physical page 0 is the dummy sink — table entries past a slot's length
    point at it and it is never read as a valid position (every position
    ``< lengths[s]`` has a real page, and everything else is masked);
  * a slot's logical page ``j`` covers absolute positions
    ``[j * page, (j + 1) * page)``;
  * ``lengths[s]`` = number of valid positions = current position + 1
    (the current token's K/V is written into the pool *before* the call).

The optional second score operand ``(q2, k2_pages)`` serves MLA absorbed
decode: scores are ``q . k + q2 . k2`` (latent + rope parts) over a
single shared KV head, and ``v_pages`` is the latent pool itself.
``scale`` is applied to the summed scores (MLA) — GQA callers pre-scale
``q`` and leave it at 1.0, matching ``attention.decode_attention``'s
operation order exactly.

``interpret=True`` runs the identical kernel through the Pallas
interpreter on CPU — how CI exercises it (same convention as
``fused_decode_matmul``).  Block shapes follow the model's head dims; on
real TPUs pad heads/pages toward (8, 128) tiles for peak DMA efficiency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, *rest, page: int,
            kh: int, g: int, window: int, softcap_val: float, scale: float,
            has_q2: bool):
    if has_q2:
        q2_ref, k2_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    s_idx = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- one page of scores: (KH, G, page) f32 ---------------------------
    q = q_ref[0].astype(jnp.float32).reshape(kh, g, q_ref.shape[-1])
    k = k_ref[0].astype(jnp.float32)                      # (page, KH, D)
    s = jnp.einsum("kgd,pkd->kgp", q, k)
    if has_q2:
        q2 = q2_ref[0].astype(jnp.float32).reshape(kh, g, q2_ref.shape[-1])
        s = s + jnp.einsum("kgd,pkd->kgp", q2,
                           k2_ref[0].astype(jnp.float32))
    if scale != 1.0:
        s = s * scale
    if softcap_val:
        s = jnp.tanh(s / softcap_val) * softcap_val

    # ---- absolute-position mask ------------------------------------------
    length = len_ref[s_idx]
    gpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = gpos < length
    if window:
        valid &= gpos > length - 1 - window
    s = jnp.where(valid, s, NEG_INF)

    # ---- online softmax accumulation across pages ------------------------
    m_prev = m_ref[...]                                   # (KH, G)
    m_new = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    pv = jnp.einsum("kgp,pkv->kgv", p, v_ref[0].astype(jnp.float32))
    acc_ref[...] = acc_ref[...] * alpha.reshape(kh * g, 1) \
        + pv.reshape(kh * g, -1)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-20).reshape(kh * g, 1)
        o_ref[0] = (acc_ref[...] / l).reshape(o_ref.shape[1:])


@functools.partial(jax.jit, static_argnames=("window", "softcap_val",
                                             "scale", "interpret"))
def paged_decode_attention(
    q: jax.Array,            # (S, H, D)   this step's queries, one per slot
    k_pages: jax.Array,      # (n_pages, page, KH, D)   physical key pool
    v_pages: jax.Array,      # (n_pages, page, KH, Dv)  physical value pool
    table: jax.Array,        # (S, P) int32 physical page per logical page
    lengths: jax.Array,      # (S,) int32   valid positions per slot
    q2: jax.Array | None = None,        # (S, H, D2)  MLA rope-part queries
    k2_pages: jax.Array | None = None,  # (n_pages, page, KH, D2)
    *,
    window: int = 0,
    softcap_val: float = 0.0,
    scale: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    """out (S, H, Dv) float32 — per-slot decode attention over paged KV.

    Numerically equivalent to gathering each slot's pages into a contiguous
    cache and running ``attention.decode_attention`` (the reference oracle
    in tests/test_paged_attention.py); the cache copy just never happens.
    """
    s_n, h, d = q.shape
    n_pages, page, kh, dk = k_pages.shape
    dv = v_pages.shape[-1]
    assert dk == d, (dk, d)
    assert h % kh == 0, (h, kh)
    g = h // kh
    pps = table.shape[1]

    in_specs = [
        pl.BlockSpec((1, h, d), lambda s, j, t, ln: (s, 0, 0)),
        pl.BlockSpec((1, page, kh, d),
                     lambda s, j, t, ln: (t[s, j], 0, 0, 0)),
        pl.BlockSpec((1, page, kh, dv),
                     lambda s, j, t, ln: (t[s, j], 0, 0, 0)),
    ]
    args = [q, k_pages, v_pages]
    if q2 is not None:
        d2 = q2.shape[-1]
        in_specs += [
            pl.BlockSpec((1, h, d2), lambda s, j, t, ln: (s, 0, 0)),
            pl.BlockSpec((1, page, kh, d2),
                         lambda s, j, t, ln: (t[s, j], 0, 0, 0)),
        ]
        args += [q2, k2_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s_n, pps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, dv), lambda s, j, t, ln: (s, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kh, g), jnp.float32),     # running max
            pltpu.VMEM((kh, g), jnp.float32),     # running normaliser
            pltpu.VMEM((h, dv), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, kh=kh, g=g, window=window,
                          softcap_val=softcap_val, scale=scale,
                          has_q2=q2 is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_n, h, dv), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), *args)
