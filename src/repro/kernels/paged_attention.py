"""Pallas TPU kernel: in-kernel paged attention over slot page tables.

Attention that consumes the scheduler's paged KV layout *directly*: the
physical page pool ``(n_pages, page, KH, D)`` plus a per-slot page table
and per-slot lengths.  Each ``(slot, q_block, page group)`` grid step
pulls ``pages_per_step`` physical pages into VMEM — the BlockSpec index
maps read the page table through scalar prefetch, so the DMA engine
walks the table and never touches pages the slot does not own — applies
the per-token causal/position mask, and folds the pages into an
online-softmax accumulator held in VMEM scratch.  No contiguous
per-slot view of the cache is ever materialised, in HBM or anywhere
else: this is the serving analogue of the paper's in-pipeline decoding
unit (§IV), which consumes operands in their at-rest layout instead of
expanding them into memory first.

Since the mixed-step generalisation the kernel serves *ragged
multi-token* queries: slot ``s`` contributes ``q_lens[s]`` consecutive
tokens (a prefill chunk, or a single decode token) out of a padded
``(S, Q)`` block, and causality is enforced per query token inside the
online-softmax loop — token ``i`` of slot ``s`` sits at absolute position
``lengths[s] - q_lens[s] + i`` and may only attend keys at positions
``<= `` its own.  Decode is the ``Q == 1`` special case
(:func:`paged_decode_attention`); prefill chunks and decode tokens of
different slots ride in the same invocation.

Layout contract (shared with ``runtime.scheduler.SlotPool``):

  * physical page 0 is the dummy sink — table entries past a slot's length
    point at it and it is never read as a valid position (every position
    ``< lengths[s]`` has a real page, and everything else is masked);
  * a slot's logical page ``j`` covers absolute positions
    ``[j * page_size, (j + 1) * page_size)`` where ``page_size`` is the
    *logical* page length — the pool's physical page dimension may be
    padded up to a sublane tile (``page_size=0`` means they coincide),
    and padded rows are masked out of the softmax like any other
    out-of-range position;
  * ``lengths[s]`` = number of valid positions *including* this step's
    tokens (the whole chunk's K/V is written into the pool *before* the
    call; the per-token causal masks preserve write-after-attend
    semantics — a query never sees a later chunk token's key);
  * padded rows/tokens (``i >= q_lens[s]``, including ``q_lens[s] == 0``
    free lanes) attend nothing and produce finite garbage the caller
    discards.

Hardware shaping (``pages_per_step``, tiled pools): with
``pages_per_step = c > 1`` each grid step carries ``c`` physical pages,
one BlockSpec per page, indexed ``table[s, j * c + i]``.  Pallas
double-buffers every input BlockSpec across grid steps, so the ``c``
page DMAs of step ``j + 1`` overlap the score/softmax compute of step
``j`` — the same async-copy overlap ``pltpu.make_async_copy`` expresses
by hand, but driven by the pipeline.  Feature dims padded toward the
(8, 128) sublane/lane tiles by ``SlotPool`` cost nothing here: zero
key/value columns contribute exactly ``0.0`` to every f32 dot product,
and padded page rows score ``NEG_INF`` and vanish in the exp.

The optional second score operand ``(q2, k2_pages)`` serves MLA absorbed
decode: scores are ``q . k + q2 . k2`` (latent + rope parts) over a
single shared KV head, and ``v_pages`` is the latent pool itself.
``scale`` is applied to the summed scores (MLA) — GQA callers pre-scale
``q`` and leave it at 1.0, matching ``attention.decode_attention``'s
operation order exactly.

Compressed pages (``kv_codec="cluster"``): when ``k_scales``/``v_scales``
are passed the pools hold int8 codebook indices and each page is decoded
*in VMEM* right after its DMA — a 256-entry codebook gather times the
per-(slot, token) scale row that rides its own scalar-prefetch
BlockSpec — before the online-softmax score ever sees it.  The fp page
never exists in HBM.  ``dequant="onehot"`` keeps the previous
one-hot-matmul lookup as a bit-identity reference.

``interpret=True`` runs the identical kernel through the Pallas
interpreter on CPU — how CI exercises it (same convention as
``fused_decode_matmul``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.kv_codec import LEVELS, ZERO_CODE

NEG_INF = -1e30


def effective_q_block(qn: int, q_block: int) -> int:
    """The query-block width the kernel will actually run.

    ``q_block=0`` means the whole ``Q`` per grid step; non-divisor
    requests round down to ``gcd(Q, q_block)`` (the same convention as
    flash_attention's ``q_chunk``).  Exposed so the scheduler can count
    the silent roundings (``kernel_qblock_rounded``)."""
    return math.gcd(qn, q_block) if q_block else qn


def _dequant(codes, scale_row, cb, mode: str):
    """Decode one int8 page in VMEM: codebook lookup * per-token scale.

    ``codes`` (page, KH, D) int8, ``scale_row`` (page,) f32, ``cb``
    (LEVELS,) f32.  ``mode="gather"`` is the direct 256-entry gather;
    ``mode="onehot"`` keeps the previous one-hot compare against an iota
    (O(page * LEVELS) select+sum) as a bit-identity reference — a
    one-hot sum of a single selected centroid is the centroid itself,
    bit for bit."""
    if mode == "gather":
        vals = cb[codes.astype(jnp.int32) + ZERO_CODE]
    else:
        flat = codes.reshape(-1, 1).astype(jnp.int32) + ZERO_CODE
        sel = flat == jax.lax.broadcasted_iota(
            jnp.int32, (flat.shape[0], LEVELS), 1)
        vals = jnp.where(sel, cb[None, :], 0.0).sum(-1).reshape(codes.shape)
    return vals * scale_row.reshape(-1, 1, 1)


def _kernel(table_ref, len_ref, qlen_ref, q_ref, *rest,
            page: int, logical: int, c: int, kh: int, g: int, qb: int,
            window: int, softcap_val: float, scale: float, has_q2: bool,
            has_codec: bool, dequant: str):
    i = 0
    k_refs = rest[i:i + c]
    i += c
    v_refs = rest[i:i + c]
    i += c
    if has_q2:
        q2_ref = rest[i]
        i += 1
        k2_refs = rest[i:i + c]
        i += c
    if has_codec:
        ks_refs = rest[i:i + c]
        i += c
        vs_refs = rest[i:i + c]
        i += c
        if has_q2:
            k2s_refs = rest[i:i + c]
            i += c
        cb_ref = rest[i]
        i += 1
    o_ref, m_ref, l_ref, acc_ref = rest[i:]
    s_idx = pl.program_id(0)
    qb_idx = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- decode this step's pages (in-kernel, codec path) ----------------
    # each of the c page refs was DMA'd by its own BlockSpec; concatenating
    # them gives one (c * page, KH, D) operand so the score einsum runs
    # once over the whole group.
    if has_codec:
        cb = cb_ref[0]
        k = jnp.concatenate([_dequant(r[0], s[0], cb, dequant)
                             for r, s in zip(k_refs, ks_refs)])
        v = jnp.concatenate([_dequant(r[0], s[0], cb, dequant)
                             for r, s in zip(v_refs, vs_refs)])
    else:
        k = jnp.concatenate([r[0].astype(jnp.float32) for r in k_refs])
        v = jnp.concatenate([r[0].astype(jnp.float32) for r in v_refs])

    # ---- one page group of scores: (KH, G, qb, c * page) f32 -------------
    q = q_ref[0].astype(jnp.float32).reshape(qb, kh, g, q_ref.shape[-1])
    s = jnp.einsum("qkgd,pkd->kgqp", q, k)
    if has_q2:
        q2 = q2_ref[0].astype(jnp.float32).reshape(
            qb, kh, g, q2_ref.shape[-1])
        if has_codec:
            k2 = jnp.concatenate([_dequant(r[0], sc[0], cb, dequant)
                                  for r, sc in zip(k2_refs, k2s_refs)])
        else:
            k2 = jnp.concatenate([r[0].astype(jnp.float32)
                                  for r in k2_refs])
        s = s + jnp.einsum("qkgd,pkd->kgqp", q2, k2)
    if scale != 1.0:
        s = s * scale
    if softcap_val:
        s = jnp.tanh(s / softcap_val) * softcap_val

    # ---- per-token causal/position mask ----------------------------------
    # query token i of this block sits at absolute position
    # lengths[s] - q_lens[s] + (qb_idx * qb + i); tokens past q_lens[s]
    # are ragged padding and attend nothing.  Key row r of this group
    # lives on logical page j * c + r // page at in-page row r % page —
    # rows at or past the logical page length are layout padding.
    length = len_ref[s_idx]
    qlen = qlen_ref[s_idx]
    qi = qb_idx * qb + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, qb, 1), 2)
    qpos = (length - qlen) + qi
    r = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, c * page), 3)
    row = r % page
    gpos = (j * c + r // page) * logical + row
    valid = (gpos <= qpos) & (qi < qlen) & (row < logical)
    if window:
        valid &= gpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    # ---- online softmax accumulation across page groups ------------------
    m_prev = m_ref[...].reshape(kh, g, qb)
    m_new = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = (l_ref[...].reshape(kh, g, qb) * alpha
                  + p.sum(-1)).reshape(kh, g * qb)
    pv = jnp.einsum("kgqp,pkv->kgqv", p, v)
    acc_ref[...] = acc_ref[...] * alpha.reshape(kh * g * qb, 1) \
        + pv.reshape(kh * g * qb, -1)
    m_ref[...] = m_new.reshape(kh, g * qb)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        l = jnp.maximum(l_ref[...].reshape(kh, g, qb), 1e-20)
        out = acc_ref[...].reshape(kh, g, qb, -1) / l[..., None]
        o_ref[0] = jnp.moveaxis(out, 2, 0).reshape(o_ref.shape[1:])


def _pad_last(x, width):
    """Zero-pad x's last dim to ``width`` (no-op when already there).
    Zero query columns meet zero key columns: the dot product is
    bit-identical to the unpadded one (x + 0.0 == x in f32)."""
    if x.shape[-1] == width:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, width - x.shape[-1])]
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("window", "softcap_val",
                                             "scale", "q_block",
                                             "page_size", "pages_per_step",
                                             "dequant", "interpret"))
def paged_mixed_attention(
    q: jax.Array,            # (S, Q, H, D)  padded per-slot query blocks
    k_pages: jax.Array,      # (n_pages, page, KH, D)   physical key pool
    v_pages: jax.Array,      # (n_pages, page, KH, Dv)  physical value pool
    table: jax.Array,        # (S, P) int32 physical page per logical page
    lengths: jax.Array,      # (S,) int32 valid positions incl. this block
    q_lens: jax.Array,       # (S,) int32 real query tokens per slot (<= Q)
    q2: jax.Array | None = None,        # (S, Q, H, D2) MLA rope-part queries
    k2_pages: jax.Array | None = None,  # (n_pages, page, KH, D2)
    k_scales: jax.Array | None = None,   # (n_pages, page) f32 codec scales
    v_scales: jax.Array | None = None,   # (n_pages, page) f32 codec scales
    k2_scales: jax.Array | None = None,  # (n_pages, page) f32 codec scales
    codebook: jax.Array | None = None,   # (LEVELS,) f32 cluster centroids
    *,
    window: int = 0,
    softcap_val: float = 0.0,
    scale: float = 1.0,
    q_block: int = 0,        # 0 = whole Q per grid step; non-divisors
    #                          round down to gcd(Q, q_block), same
    #                          convention as flash_attention's q_chunk
    page_size: int = 0,      # logical tokens per page; 0 = the pools'
    #                          physical page dim (i.e. no row padding)
    pages_per_step: int = 1,  # physical pages DMA'd per grid step
    dequant: str = "gather",  # codec lookup: "gather" | "onehot"
    interpret: bool = False,
) -> jax.Array:
    """out (S, Q, H, Dv) float32 — ragged mixed-step paged attention.

    Numerically equivalent to gathering each slot's pages into a
    contiguous cache and running the gathered reference attention
    (``attention.decode_attention`` / ``attention.chunk_attention`` — the
    oracles in tests); the cache copy just never happens.  Rows beyond
    ``q_lens[s]`` are padding: their output is finite garbage the caller
    must ignore.

    When ``k_scales`` is given (``kv_codec="cluster"``) the K/V pools —
    and ``k2_pages`` if present — hold int8 ``kv_codec`` codes; each
    page is decoded in VMEM against ``codebook`` and its per-token
    scale row before scoring.  Equivalent to decoding the whole pool
    up front, without ever materialising the fp pool.

    Tiled pools: ``q``/``q2`` narrower than the pools' feature dims are
    zero-padded up to them here (the caller slices the output back to
    its model width), and ``page_size < k_pages.shape[1]`` declares the
    trailing physical rows of every page to be layout padding.  The
    output value width is the *pool's* ``Dv`` — callers using padded
    value pools slice ``out[..., :dv_model]``.
    """
    s_n, qn, h, d = q.shape
    n_pages, page, kh, dk = k_pages.shape
    dv = v_pages.shape[-1]
    logical = page_size or page
    assert 0 < logical <= page, (logical, page)
    assert dk >= d, (dk, d)
    assert h % kh == 0, (h, kh)
    g = h // kh
    q = _pad_last(q, dk)
    c = max(int(pages_per_step), 1)
    n_groups = -(-table.shape[1] // c)
    if n_groups * c != table.shape[1]:
        # pad the table with dummy-page entries so every grid step walks
        # exactly c pages; the extra logical pages sit past the slot
        # capacity, so every row of them is masked.
        table = jnp.pad(table, ((0, 0), (0, n_groups * c - table.shape[1])))
    qb = effective_q_block(qn, q_block)
    nqb = qn // qb

    def walk(i, block):
        # one BlockSpec per page of the group: page i of grid step j is
        # physical page table[s, j * c + i]; Pallas pipelines the next
        # step's c DMAs behind this step's compute.
        return pl.BlockSpec(
            block, lambda s, qi, j, t, ln, ql, i=i: (t[s, j * c + i],)
            + (0,) * (len(block) - 1))

    in_specs = [
        pl.BlockSpec((1, qb, h, dk),
                     lambda s, qi, j, t, ln, ql: (s, qi, 0, 0)),
        *[walk(i, (1, page, kh, dk)) for i in range(c)],
        *[walk(i, (1, page, kh, dv)) for i in range(c)],
    ]
    args = [q, *[k_pages] * c, *[v_pages] * c]
    if q2 is not None:
        d2 = k2_pages.shape[-1]
        q2 = _pad_last(q2, d2)
        in_specs += [
            pl.BlockSpec((1, qb, h, d2),
                         lambda s, qi, j, t, ln, ql: (s, qi, 0, 0)),
            *[walk(i, (1, page, kh, d2)) for i in range(c)],
        ]
        args += [q2, *[k2_pages] * c]
    if k_scales is not None:
        # one scale row per physical page, walked through the page table
        # exactly like the pools themselves
        in_specs += [walk(i, (1, page)) for i in range(c)]
        args += [k_scales.astype(jnp.float32)] * c
        in_specs += [walk(i, (1, page)) for i in range(c)]
        args += [v_scales.astype(jnp.float32)] * c
        if q2 is not None:
            in_specs += [walk(i, (1, page)) for i in range(c)]
            args += [k2_scales.astype(jnp.float32)] * c
        in_specs += [pl.BlockSpec((1, LEVELS),
                                  lambda s, qi, j, t, ln, ql: (0, 0))]
        args += [jnp.asarray(codebook, jnp.float32).reshape(1, LEVELS)]
        assert dequant in ("gather", "onehot"), dequant

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(s_n, nqb, n_groups),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, qb, h, dv),
                               lambda s, qi, j, t, ln, ql: (s, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kh, g * qb), jnp.float32),    # running max
            pltpu.VMEM((kh, g * qb), jnp.float32),    # running normaliser
            pltpu.VMEM((h * qb, dv), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, logical=logical, c=c, kh=kh,
                          g=g, qb=qb, window=window,
                          softcap_val=softcap_val, scale=scale,
                          has_q2=q2 is not None,
                          has_codec=k_scales is not None, dequant=dequant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_n, qn, h, dv), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32),
      jnp.asarray(q_lens, jnp.int32), *args)


def paged_decode_attention(
    q: jax.Array,            # (S, H, D)   this step's queries, one per slot
    k_pages: jax.Array,
    v_pages: jax.Array,
    table: jax.Array,
    lengths: jax.Array,      # (S,) int32   valid positions per slot
    q2: jax.Array | None = None,
    k2_pages: jax.Array | None = None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
    k2_scales: jax.Array | None = None,
    codebook: jax.Array | None = None,
    *,
    window: int = 0,
    softcap_val: float = 0.0,
    scale: float = 1.0,
    page_size: int = 0,
    pages_per_step: int = 1,
    dequant: str = "gather",
    interpret: bool = False,
) -> jax.Array:
    """out (S, H, Dv) float32 — single-token decode, the ``Q == 1``
    special case of :func:`paged_mixed_attention` (each slot's one query
    sits at position ``lengths[s] - 1``)."""
    out = paged_mixed_attention(
        q[:, None], k_pages, v_pages, table, lengths,
        jnp.ones((q.shape[0],), jnp.int32),
        None if q2 is None else q2[:, None], k2_pages,
        k_scales, v_scales, k2_scales, codebook,
        window=window, softcap_val=softcap_val, scale=scale,
        page_size=page_size, pages_per_step=pages_per_step,
        dequant=dequant, interpret=interpret)
    return out[:, 0]
