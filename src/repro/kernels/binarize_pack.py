"""Pallas TPU kernel: runtime activation binarisation + sequence-aligned
packing.

Completes the paper's datapath on-chip: activations are sign-binarised and
channel-packed (the RSign + packing-unit input side of Fig. 6) without a
round-trip of unpacked bits through HBM.  Output layout matches
``bitpack.pack_gemm_operand`` / ``ref.binarize_pack``: per 288-element
K-block, word j packs bit j of 32 consecutive 9-bit sequences.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import BLOCK_K


def _kernel(x_ref, out_ref):
    x = x_ref[...]                                   # (bm, 288)
    bm = x.shape[0]
    bits = (x >= 0).astype(jnp.uint32).reshape(bm, 32, 9)
    lanes = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    out_ref[:, 0, :] = (bits << lanes).sum(1, dtype=jnp.uint32)  # (bm, 9)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def binarize_pack(x: jax.Array, *, bm: int = 512,
                  interpret: bool = False) -> jax.Array:
    """(M, K) real -> (M, ceil(K/288), 9) uint32 packed sign bits.

    K is zero-padded (-1s) to a whole number of 288-bit blocks; the
    contraction kernels correct for the padding via k_true.
    """
    m, k = x.shape
    kp = -(-k // BLOCK_K) * BLOCK_K
    bm = min(bm, m)
    mp = -(-m // bm) * bm
    # pad with -1 so padded positions binarise to bit 0
    x = jnp.pad(x, ((0, mp - m), (0, kp - k)), constant_values=-1.0)
    g = kp // BLOCK_K
    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, g),
        in_specs=[pl.BlockSpec((bm, BLOCK_K), lambda mi, gi: (mi, gi))],
        out_specs=pl.BlockSpec((bm, 1, 9), lambda mi, gi: (mi, gi, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, g, 9), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:m]
