"""Pallas TPU kernel: fused Huffman-decode + xnor/popcount GEMM.

The end-to-end analogue of the paper's hardware pipeline: compressed weights
stream HBM->VMEM, are decoded and channel-packed on the fly (the *decoding
unit*), and feed the binary contraction (the xnor/popcount datapath) without
ever materialising uncompressed weights in HBM.  The HBM weight traffic is
therefore ``1/ratio_tiled`` of the baseline kernel's — this is the paper's
1.35x speedup mechanism expressed as a roofline memory-term reduction.

Compressed layout (``repro.core.compression.compress_gemm_fused``):
  * weight sequences (N, G) are re-ordered into (NB, GB, 32, 32) blocks —
    32 N-rows x 32 sequences (= one 288-bit K block);
  * each (nb, gb) block is one decode tile: 1024 sequences over S=128
    substreams x C=8 codes;
  * words: (NB, GB, W, S) uint32.

Grid = (NB, MB, GB) with GB innermost: the (bm, 32) accumulator lives in
VMEM scratch across the K sweep; weights are decoded once per grid step and
consumed immediately from VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.huffman_decode import TABLE_SIZE, decode_step

SUB = 128         # substreams
DEFAULT_CODES = 8  # codes per substream per tile; N rows per tile = 4*codes


def _kernel(words_ref, x_ref, tables_ref, out_ref, acc_ref, w_scratch,
            *, ngb: int, k_true: int, total_bits: int, gather: str,
            codes: int):
    bn = 4 * codes
    gb = pl.program_id(2)

    @pl.when(gb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- decode unit: one tile -> bn rows x one K-block of packed words ---
    words = words_ref[0, 0]                         # (W, S)
    tables = tables_ref[...] if gather == "bitplane" else tables_ref[0]

    def body(ci, bitpos):
        val, bitpos = decode_step(words, bitpos, tables, gather)
        pl.store(w_scratch, (pl.dslice(ci, 1), slice(None)), val[None, :])
        return bitpos

    jax.lax.fori_loop(0, codes, body, jnp.zeros(SUB, jnp.int32))

    # ---- packing unit: (C, S) sequences -> (bn rows, 9 taps) uint32 -------
    seqs = w_scratch[...].reshape(bn, 32).astype(jnp.uint32)  # row-major tile
    lane = jnp.arange(32, dtype=jnp.uint32)[None, :]
    w_words = []
    for j in range(9):                              # tap j, MSB-first
        bit_j = (seqs >> (8 - j)) & 1
        w_words.append((bit_j << lane).sum(-1, dtype=jnp.uint32))
    w_packed = jnp.stack(w_words, axis=-1)          # (32, 9)

    # ---- xnor/popcount contraction ----------------------------------------
    x = x_ref[:, 0, :]                              # (bm, 9) uint32
    xnor = ~(x[:, None, :] ^ w_packed[None, :, :])  # (bm, bn, 9)
    acc_ref[...] += jax.lax.population_count(xnor).sum(-1).astype(jnp.int32)

    @pl.when(gb == ngb - 1)
    def _done():
        n_pad = total_bits - k_true
        out_ref[...] = 2 * (acc_ref[...] - n_pad) - k_true


@functools.partial(jax.jit, static_argnames=("k_true", "n_true", "bm",
                                             "gather", "codes", "interpret"))
def fused_decode_matmul(
    words: jax.Array,       # (NB, GB, W, S) uint32 compressed weights
    x_words: jax.Array,     # (M, G, 9) uint32 packed activations
    tables: jax.Array,      # (160,) int32 | (5, 9) uint32 bit-plane LUT
    *,
    k_true: int,
    n_true: int,
    bm: int = 256,
    gather: str = "onehot",
    codes: int = DEFAULT_CODES,
    interpret: bool = False,
) -> jax.Array:
    """out (M, n_true) int32 = packed x  .  decoded(words) with +-1 semantics.

    ``codes`` must match the layout's codes_per_sub (tile = 4*codes N-rows).
    """
    bn = 4 * codes
    nb, ngb, w, s = words.shape
    m, g, nine = x_words.shape
    assert s == SUB and nine == 9, (s, nine)
    assert g == ngb, f"activation K blocks {g} != weight tiles {ngb}"
    bm = min(bm, m)
    mp = -(-m // bm) * bm
    x_words = jnp.pad(x_words, ((0, mp - m), (0, 0), (0, 0)))
    if gather == "bitplane":
        tables = tables.astype(jnp.uint32).reshape(5, 9)
        tspec = pl.BlockSpec((5, 9), lambda ni, mi, gi: (0, 0))
    else:
        tables = tables.astype(jnp.int32).reshape(1, TABLE_SIZE)
        tspec = pl.BlockSpec((1, TABLE_SIZE), lambda ni, mi, gi: (0, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, ngb=ngb, k_true=k_true,
                          total_bits=ngb * 288, gather=gather, codes=codes),
        grid=(nb, mp // bm, ngb),
        in_specs=[
            pl.BlockSpec((1, 1, w, s), lambda ni, mi, gi: (ni, gi, 0, 0)),
            pl.BlockSpec((bm, 1, 9), lambda ni, mi, gi: (mi, gi, 0)),
            tspec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda ni, mi, gi: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, nb * bn), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((codes, SUB), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(words, x_words, tables)
    return out[:m, :n_true]
