"""Public jit'd wrappers around the Pallas kernels.

Every op has a pure-jnp oracle in :mod:`repro.kernels.ref`; the wrappers
auto-select interpret mode off-TPU so the same call sites run everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression
from repro.kernels import ref
from repro.kernels.binarize_pack import binarize_pack as binarize_pack_kernel
from repro.kernels.binary_contraction import binary_contraction
from repro.kernels.fused_decode_contraction import fused_decode_matmul
from repro.kernels.huffman_decode import huffman_decode, pack_bitplane_tables


def _interpret(flag: bool | None) -> bool:
    return jax.default_backend() != "tpu" if flag is None else flag


def binarize_pack(x: jax.Array, *, use_kernel: bool = False,
                  interpret: bool | None = None) -> jax.Array:
    """(M, K) real -> (M, G, 9) packed sign bits; Pallas kernel on TPU."""
    if use_kernel:
        return binarize_pack_kernel(x, interpret=_interpret(interpret))
    return ref.binarize_pack(x)


# ---------------------------------------------------------------------------
# binary matmul (uncompressed baseline path)
# ---------------------------------------------------------------------------

def binary_matmul_packed(
    x_words: jax.Array,       # (M, G, 9) uint32
    w_words: jax.Array,       # (N, G, 9) uint32
    k_true: int,
    *,
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """(M, N) int32 +-1 dot of packed operands."""
    xw = x_words.reshape(x_words.shape[0], -1)
    ww = w_words.reshape(w_words.shape[0], -1)
    return binary_contraction(
        xw, ww, k_true=k_true, interpret=_interpret(interpret), **block_kw)


def binary_matmul(
    x: jax.Array,             # (M, K) real
    w: jax.Array,             # (N, K) real latent weights
    *,
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """sign(x) @ sign(w).T via the packed xnor/popcount kernel -> (M, N) f32."""
    k = x.shape[-1]
    xw = ref.binarize_pack(x)
    ww = ref.binarize_pack(w)
    return binary_matmul_packed(
        xw, ww, k, interpret=interpret, **block_kw).astype(jnp.float32)


# ---------------------------------------------------------------------------
# compressed path (paper's contribution)
# ---------------------------------------------------------------------------

def compressed_binary_matmul(
    x: jax.Array,                       # (M, K) real
    words: jax.Array,                   # (NB, GB, W, S) uint32
    tables: jax.Array,                  # (160,) | (5, 9) bit-plane
    *,
    k_true: int,
    n_true: int,
    gather: str = "onehot",
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """sign(x) @ decoded-weights.T, decoding fused into the GEMM."""
    xw = ref.binarize_pack(x)
    return fused_decode_matmul(
        words, xw, tables, k_true=k_true, n_true=n_true, gather=gather,
        interpret=_interpret(interpret), **block_kw).astype(jnp.float32)


def decode_sequences(
    words: jax.Array, tables: jax.Array, *, c: int, n_seqs: int,
    gather: str = "onehot", interpret: bool | None = None,
) -> jax.Array:
    """Standalone decode: tiled stream -> flat (n_seqs,) int32 sequences."""
    out = huffman_decode(words, tables, c=c, gather=gather,
                         interpret=_interpret(interpret))
    return ref.tiled_to_sequences(out, n_seqs)


# ---------------------------------------------------------------------------
# 3x3 BNN convolution (im2col + contraction)
# ---------------------------------------------------------------------------

def _im2col_bits(x: jax.Array, stride: int) -> tuple[jax.Array, tuple[int, ...]]:
    """NHWC real -> ((N*Ho*Wo, Cin*9) {0,1} bits, out spatial shape).

    Zero bits encode -1, so SAME zero-padding of the *bit* tensor implements
    the BNN's -1 padding exactly (ref.binary_conv3x3 semantics).
    """
    n, h, w, cin = x.shape
    bits = (x >= 0).astype(jnp.float32)
    patches = jax.lax.conv_general_dilated_patches(
        bits, (3, 3), (stride, stride), padding=((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    ho, wo = patches.shape[1], patches.shape[2]
    return patches.reshape(n * ho * wo, cin * 9), (n, ho, wo)


def binary_conv3x3(
    x: jax.Array,             # (N, H, W, Cin) real
    w: jax.Array,             # (Cout, Cin, 3, 3) real latent weights
    *,
    stride: int = 1,
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """BNN 3x3 conv via im2col + packed contraction -> (N, Ho, Wo, Cout) f32."""
    cout, cin = w.shape[:2]
    cols, (n, ho, wo) = _im2col_bits(x, stride)
    xw = ref.pack_bits_runtime(cols.astype(jnp.uint32))
    w_bits = (w >= 0).astype(jnp.uint32).reshape(cout, cin * 9)
    ww = ref.pack_bits_runtime(w_bits)
    out = binary_matmul_packed(xw, ww, cin * 9, interpret=interpret, **block_kw)
    return out.reshape(n, ho, wo, cout).astype(jnp.float32)


def compressed_binary_conv3x3(
    x: jax.Array,                       # (N, H, W, Cin) real
    words: jax.Array,                   # fused layout of (Cout, Cin*9) bits
    tables: jax.Array,
    *,
    cin: int,
    cout: int,
    stride: int = 1,
    gather: str = "onehot",
    interpret: bool | None = None,
    **block_kw,
) -> jax.Array:
    """BNN 3x3 conv with weights Huffman-decoded inside the GEMM kernel."""
    cols, (n, ho, wo) = _im2col_bits(x, stride)
    xw = ref.pack_bits_runtime(cols.astype(jnp.uint32))
    out = fused_decode_matmul(
        words, xw, tables, k_true=cin * 9, n_true=cout, gather=gather,
        interpret=_interpret(interpret), **block_kw)
    return out.reshape(n, ho, wo, cout).astype(jnp.float32)


# ---------------------------------------------------------------------------
# offline helpers: numpy weights -> device arrays for the compressed path
# ---------------------------------------------------------------------------

def prepare_compressed_gemm(w_bits: np.ndarray, cluster: bool = True,
                            gather: str = "onehot", codes: int = 8):
    """(N, K) {0,1} -> (words, tables, meta dict) ready for the fused kernel."""
    fc = compression.compress_gemm_fused(w_bits, cluster=cluster,
                                         codes_per_sub=codes)
    tables = fc.ct.decode_tables()
    if gather == "bitplane":
        tables = pack_bitplane_tables(tables)
    return (jnp.asarray(fc.words), jnp.asarray(tables),
            dict(k_true=fc.k_true, n_true=fc.n_true, codes=codes,
                 ratio_stream=fc.ct.ratio_stream(),
                 ratio_tiled=fc.ratio_tiled()))


def prepare_compressed_conv(w_bits: np.ndarray, cluster: bool = True,
                            gather: str = "onehot", codes: int = 8):
    """(Cout, Cin, 3, 3) {0,1} -> fused-kernel operands (GEMM view)."""
    cout, cin = w_bits.shape[:2]
    return prepare_compressed_gemm(
        w_bits.reshape(cout, cin * 9), cluster=cluster, gather=gather,
        codes=codes)
