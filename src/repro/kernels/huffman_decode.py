"""Pallas TPU kernel: substream-parallel simplified-Huffman decode.

The TPU adaptation of the paper's *decoding unit* (DESIGN.md §2):

  * paper's input buffer  -> the (W, S) compressed tile streamed HBM->VMEM by
    the pallas grid pipeline (double-buffered DMA = the paper's "fetch while
    decoding" overlap);
  * paper's stream parser -> vectorised prefix classification on 128 lanes;
  * paper's banked 1 KB scratchpad -> the 160-entry decode table in VMEM;
  * serial bitstream -> S=128 independent substreams decoded in lockstep,
    the per-lane bit cursor being the only sequential state.

Per grid step we decode one tile: C codes x S substreams -> (C, S) int32
sequence values.  The variable-length chain is a ``fori_loop`` over C; all
work inside an iteration is lane-parallel.

Two table-gather strategies (perf-iteration subject, EXPERIMENTS.md §Perf):
  * ``gather="onehot"``   — 160-row one-hot select (paper-faithful indirection
                            table, baseline);
  * ``gather="bitplane"`` — bit-sliced LUT: the 160 entries are packed into a
                            (5, 9) uint32 bit-plane array; a 5-row one-hot +
                            9 shifts replaces the 160-row reduce (~3x fewer
                            VPU ops).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TABLE_SIZE = 160


def pack_bitplane_tables(tables_flat: np.ndarray) -> np.ndarray:
    """(160,) int32 -> (5, 9) uint32 bit-plane LUT.

    entry (g, j) packs bit (8-j) of table values for flat indices
    [32g, 32g+32): bit c of word (g, j) = tap j of table[32g + c].
    """
    t = np.asarray(tables_flat, dtype=np.uint32).reshape(5, 32)
    taps = np.arange(9)
    bits = (t[:, :, None] >> (8 - taps)[None, None, :]) & 1   # (5, 32, 9)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits.transpose(0, 2, 1).astype(np.uint32)
            << shifts).sum(-1, dtype=np.uint32)               # (5, 9)


def decode_step(words, bitpos, tables, gather: str):
    """One lane-parallel decode step: (W, S) words + (S,) cursors ->
    (values (S,), new cursors (S,)).  Shared by this kernel and the fused
    decode+GEMM kernel."""
    w_rows = words.shape[0]
    word_idx = bitpos >> 5
    bit_off = bitpos & 31
    rows = jax.lax.broadcasted_iota(jnp.int32, (w_rows, words.shape[1]), 0)
    w0 = jnp.sum(jnp.where(rows == word_idx[None, :], words, 0),
                 axis=0, dtype=jnp.uint32)
    nidx = jnp.minimum(word_idx + 1, w_rows - 1)
    w1 = jnp.sum(jnp.where(rows == nidx[None, :], words, 0),
                 axis=0, dtype=jnp.uint32)
    off = bit_off.astype(jnp.uint32)
    lo = jnp.where(off > 0, w1 >> (32 - jnp.maximum(off, 1)), 0)
    window = ((w0 << off) | lo) >> 20                 # 12-bit peek
    top3 = window >> 9
    is0 = top3 < 4
    is1 = (top3 >> 1) == 2
    is2 = top3 == 6
    is3 = top3 == 7
    flat_idx = jnp.where(
        is0, (window >> 6) & 31,
        jnp.where(is1, 32 + ((window >> 4) & 63), 96 + ((window >> 3) & 63)),
    ).astype(jnp.int32)
    if gather == "onehot":
        tidx = jax.lax.broadcasted_iota(jnp.int32, (TABLE_SIZE, len(bitpos)), 0)
        tval = jnp.sum(
            jnp.where(tidx == flat_idx[None, :], tables[:, None], 0), axis=0)
    elif gather == "bitplane":
        g = flat_idx >> 5                              # (S,) in [0, 5)
        c = (flat_idx & 31).astype(jnp.uint32)
        grows = jax.lax.broadcasted_iota(jnp.int32, (5, len(bitpos)), 0)
        tval = jnp.zeros(len(bitpos), jnp.int32)
        for j in range(9):
            plane = jnp.sum(
                jnp.where(grows == g[None, :], tables[:, j][:, None], 0),
                axis=0, dtype=jnp.uint32)
            tval |= (((plane >> c) & 1) << (8 - j)).astype(jnp.int32)
    else:  # pragma: no cover
        raise ValueError(gather)
    val = jnp.where(is3, (window & 511).astype(jnp.int32), tval)
    length = jnp.where(is0, 6, jnp.where(is1, 8, jnp.where(is2, 9, 12)))
    return val, bitpos + length.astype(jnp.int32)


def _kernel(words_ref, tables_ref, out_ref, *, c: int, gather: str):
    words = words_ref[0]                               # (W, S)
    tables = tables_ref[...] if gather == "bitplane" else tables_ref[0]

    def body(ci, bitpos):
        val, bitpos = decode_step(words, bitpos, tables, gather)
        pl.store(out_ref, (pl.dslice(0, 1), pl.dslice(ci, 1), slice(None)),
                 val[None, None, :])
        return bitpos

    jax.lax.fori_loop(0, c, body, jnp.zeros(words.shape[1], jnp.int32))


@functools.partial(jax.jit, static_argnames=("c", "gather", "interpret"))
def huffman_decode(
    words: jax.Array,        # (T, W, S) uint32 tiled compressed stream
    tables: jax.Array,       # (160,) int32  |  (5, 9) uint32 bit-plane LUT
    *,
    c: int,                  # codes per substream per tile
    gather: str = "onehot",
    interpret: bool = False,
) -> jax.Array:
    """Decode the tiled stream -> (T, C, S) int32 sequence values."""
    t, w, s = words.shape
    if gather == "bitplane":
        tables = tables.astype(jnp.uint32).reshape(5, 9)
        tspec = pl.BlockSpec((5, 9), lambda ti: (0, 0))
    else:
        tables = tables.astype(jnp.int32).reshape(1, TABLE_SIZE)
        tspec = pl.BlockSpec((1, TABLE_SIZE), lambda ti: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, c=c, gather=gather),
        grid=(t,),
        in_specs=[pl.BlockSpec((1, w, s), lambda ti: (ti, 0, 0)), tspec],
        out_specs=pl.BlockSpec((1, c, s), lambda ti: (ti, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, c, s), jnp.int32),
        interpret=interpret,
    )(words, tables)
