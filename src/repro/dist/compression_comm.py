"""Compressed gradient exchange (1-bit / int8 allreduce with error feedback).

The paper compresses *weights*; the training substrate reuses the same
insight on the wire: inside a pure-DP ``shard_map`` the only cross-replica
traffic is packed sign bits (+ one scale) or int8 levels per tensor.  Error
feedback (Seide et al., 2014) carries the quantisation residual to the next
step, so the compressed optimizer tracks the exact one in expectation.

All functions run *inside* shard_map over the DP axes: ``axes`` names the
mapped mesh axes for the psum/pmean collectives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def init_error_feedback(grads):
    """Zero residual state, one leaf per gradient leaf."""
    return jax.tree_util.tree_map(jnp.zeros_like, grads)


def onebit_allreduce(g: jax.Array, ef: jax.Array, axes):
    """1-bit compressed allreduce of one tensor -> (mean update, new ef).

    Emits sign(v) * scale where v = g + ef and scale = global mean |v|;
    the residual v - emitted stays local in the error-feedback state.
    """
    v = g + ef
    scale = jax.lax.pmean(jnp.mean(jnp.abs(v)), axes)
    scale = jnp.maximum(scale, _EPS)
    signs = jnp.sign(v)
    local = signs * scale                    # what this replica contributed
    out = jax.lax.pmean(signs, axes) * scale
    return out, v - local


def int8_allreduce(g: jax.Array, ef: jax.Array, axes):
    """int8 compressed allreduce: symmetric per-tensor quantisation."""
    v = g + ef
    scale = jax.lax.pmax(jnp.max(jnp.abs(v)), axes) / 127.0
    scale = jnp.maximum(scale, _EPS)
    q = jnp.clip(jnp.round(v / scale), -127, 127)
    local = q * scale
    out = jax.lax.pmean(q, axes) * scale
    return out, v - local


def compress_grads(grads, ef, axes, *, mode: str = "onebit"):
    """Compress+exchange a gradient pytree -> (reduced grads, new ef)."""
    fn = {"onebit": onebit_allreduce, "int8": int8_allreduce}[mode]
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs, efs = zip(*(fn(gl, el, axes) for gl, el in zip(flat_g, flat_e)))
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, efs))
