"""Sharding rules + mesh context (DESIGN.md §4/§5).

Logical axis vocabulary: ``"batch"`` maps to the data-parallel mesh axes
(``("pod", "data")`` when multi-pod, else ``("data",)``), ``"model"`` to the
tensor-parallel axis.  :func:`constrain` is the only entry point model code
uses — it is an exact no-op when no mesh is active (tests / shard_map
bodies), so the model files stay importable and runnable on one CPU device.

Every spec emitted here is *safe*: a mesh axis is only assigned to a tensor
dimension it divides, so jit never sees an invalid sharding even for odd
vocab sizes or reduced test configs.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# active mesh, set by use_mesh(); None = mesh-less (constrain no-ops)
_ACTIVE: list[Any] = []
_DISABLED: list[bool] = []


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for constrain() in this block (re-entrant)."""
    _ACTIVE.append(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE.pop()


@contextlib.contextmanager
def no_mesh():
    """Suspend constraints (e.g. inside shard_map bodies, already per-shard)."""
    _DISABLED.append(True)
    try:
        yield
    finally:
        _DISABLED.pop()


def current_mesh():
    if _DISABLED or not _ACTIVE:
        return None
    return _ACTIVE[-1]


# ---------------------------------------------------------------------------
# axis bookkeeping
# ---------------------------------------------------------------------------

def _axis_size(mesh, name: str) -> int:
    try:
        return int(mesh.shape.get(name, 1))
    except AttributeError:
        return 1


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel mesh axes, outermost first."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_axes(mesh) -> tuple[str, ...]:
    return dp_axes(mesh)


def _dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= _axis_size(mesh, a)
    return n


def _entry_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= _axis_size(mesh, a)
        return n
    return _axis_size(mesh, entry)


def _resolve(mesh, entry):
    """Map a logical entry to concrete mesh axes ("batch" -> DP axes)."""
    if entry is None:
        return None
    if isinstance(entry, (tuple, list)):
        axes = tuple(a for e in entry for a in (_resolve_one(mesh, e) or ()))
        return axes or None
    one = _resolve_one(mesh, entry)
    if one is None:
        return None
    return one if len(one) > 1 else one[0]


def _resolve_one(mesh, name: str) -> tuple[str, ...] | None:
    if name == "batch":
        return dp_axes(mesh) or None
    if name in mesh.axis_names:
        return (name,)
    return None


def safe_spec(mesh, shape: tuple[int, ...], *axes) -> P:
    """PartitionSpec with non-divisible / absent axes dropped to None."""
    entries = list(axes) + [None] * (len(shape) - len(axes))
    out = []
    for dim, entry in zip(shape, entries[: len(shape)]):
        resolved = _resolve(mesh, entry)
        if resolved is not None and dim % _entry_size(mesh, resolved) == 0:
            out.append(resolved)
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint under the active mesh; identity off-mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = safe_spec(mesh, x.shape, *axes)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def seq_shard_attention(q, k, v):
    """Sequence-parallel attention layout: q rows sharded over "model",
    k/v replicated (reduced per-device score block; DESIGN.md §4)."""
    q = constrain(q, "batch", "model", None, None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    return q, k, v


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

# leaf names whose 2-d weight shards the OUTPUT (last) dim over "model"
_COL_SHARDED = {
    "wq", "wk", "wv", "up", "gate", "w_uq", "w_dq", "w_uk", "w_uv",
    "w_x", "w_gate", "w_i", "w_r", "lm_head",
}
# leaf names whose 2-d weight shards the INPUT (first) dim over "model"
_ROW_SHARDED = {"wo", "down", "w_out"}


def _base_spec(leaf: str, shape: tuple[int, ...], mesh) -> tuple:
    """Spec for the trailing (unstacked) dims of one parameter."""
    model = _axis_size(mesh, "model")
    nd = len(shape)
    if nd <= 1:
        return (None,) * nd
    if nd == 3 and leaf.startswith("w_"):        # MoE expert weights (E, a, b)
        e = shape[0]
        if model > 1 and e % model == 0:         # true expert parallelism
            return ("model", None, None)
        # per-expert TP on the d_ff axis (gate/up: last dim; down: middle)
        if leaf == "w_down":
            return (None, "model", None)
        return (None, None, "model")
    if nd == 2:
        if leaf == "embed":
            return ("model", None) if shape[0] % max(model, 1) == 0 \
                else (None, None)
        if leaf in _COL_SHARDED:
            return (None, "model")
        if leaf in _ROW_SHARDED:
            return ("model", None)
    return (None,) * nd


def param_spec(name: str, shape: tuple[int, ...], mesh,
               *, fsdp: bool = False) -> P:
    """Sharding spec of one named parameter (name = "/".join(tree path)).

    Stacked scan-over-layers parameters carry extra *leading* dims; the rule
    is matched on the leaf name and applied to the trailing dims.
    """
    leaf = name.rsplit("/", 1)[-1]
    base = _base_spec(leaf, shape, mesh)
    lead = len(shape) - len(base)
    entries = [None] * lead + list(base)
    # validate divisibility of the rule's choices
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is not None and dim % _entry_size(mesh, entry) != 0:
            entries[i] = None
    if fsdp:
        dp = dp_axes(mesh)
        dsz = _dp_size(mesh)
        if dp and len(shape) >= 2:
            for i in range(lead, len(shape)):
                if entries[i] is None and shape[i] % dsz == 0:
                    entries[i] = tuple(dp)
                    break
    return P(*entries)


def path_name(path) -> str:
    """jax tree key path -> "a/b/0/c" string (shared naming convention)."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


_path_name = path_name


def params_shardings(params_sds, mesh, *, fsdp: bool = False):
    """Pytree of NamedShardings for a params pytree (of arrays or SDS)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path_name(path), leaf.shape, mesh, fsdp=fsdp)),
        params_sds)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def _leading_batch_spec(mesh, shape: tuple[int, ...]) -> P:
    if not shape:
        return P()
    return safe_spec(mesh, shape, "batch")


def batch_shardings(batch_sds, mesh):
    """DP-shard the leading axis of every batch leaf; scalars replicated."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, _leading_batch_spec(mesh, leaf.shape)),
        batch_sds)


def cache_shardings(cache_sds, mesh):
    """KV/state caches: batch-major leaves DP-sharded on the leading axis."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, _leading_batch_spec(mesh, leaf.shape)),
        cache_sds)
