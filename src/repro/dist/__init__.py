"""Distribution layer: mesh context, sharding rules, compressed gradient
collectives, and fault-tolerant step supervision.

Model code talks to this package only through :func:`sharding.constrain`
(a mesh-aware no-op off-mesh), so every model file runs unchanged on a
single CPU device, the CI mesh, and the 16x16 production pod.
"""
