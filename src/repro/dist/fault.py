"""Fault tolerance for long training runs: bad-step containment, straggler
detection, periodic checkpoints, and elastic re-mesh restore.

The Supervisor wraps the jitted train step.  A step whose loss is non-finite
is *contained*: the state update is dropped and the run continues; too many
consecutive bad steps abort the run (the data or the optimizer is broken,
not one batch).  Step durations are tracked against their running median to
flag stragglers (preempted hosts, thermal throttling) in the event log.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time

from repro.ckpt import checkpoint as ckpt

_MIN_HISTORY = 5          # steps before straggler detection engages
_ABS_FLOOR_S = 0.01       # ignore sub-10ms jitter


@dataclasses.dataclass
class FaultConfig:
    max_consecutive_bad: int = 3
    straggler_factor: float = 3.0      # x median duration; 0 disables
    ckpt_dir: str = ""
    ckpt_every: int = 50


@dataclasses.dataclass
class StepReport:
    loss: float
    duration: float
    skipped: bool = False
    straggler: bool = False


class Supervisor:
    def __init__(self, cfg: FaultConfig | None = None):
        self.cfg = cfg or FaultConfig()
        self.events: list[str] = []
        self._consecutive_bad = 0
        self._durations: list[float] = []

    # -- stepping ----------------------------------------------------------
    def run_step(self, step_fn, state, batch, step: int):
        """Execute one supervised step -> (state, StepReport).

        Non-finite loss drops the update (old state is returned); the
        ``max_consecutive_bad``-th such step in a row raises RuntimeError.
        """
        t0 = time.monotonic()
        new_state, loss = step_fn(state, batch)
        loss_f = float(loss)               # blocks until the step finishes
        dur = time.monotonic() - t0

        straggler = False
        if self.cfg.straggler_factor and len(self._durations) >= _MIN_HISTORY:
            med = statistics.median(self._durations)
            if dur > self.cfg.straggler_factor * med and \
                    dur - med > _ABS_FLOOR_S:
                straggler = True
                self.events.append(
                    f"step {step}: straggler ({dur:.3f}s vs median "
                    f"{med:.3f}s)")
        self._durations.append(dur)
        if len(self._durations) > 64:
            del self._durations[0]

        if not math.isfinite(loss_f):
            self._consecutive_bad += 1
            self.events.append(f"step {step}: bad loss ({loss_f}), "
                               f"update dropped")
            if self._consecutive_bad >= self.cfg.max_consecutive_bad:
                raise RuntimeError(
                    f"{self._consecutive_bad} consecutive bad steps "
                    f"(last loss {loss_f} at step {step})")
            return state, StepReport(loss=loss_f, duration=dur, skipped=True,
                                     straggler=straggler)

        self._consecutive_bad = 0
        return new_state, StepReport(loss=loss_f, duration=dur,
                                     straggler=straggler)

    # -- checkpoints -------------------------------------------------------
    def maybe_restore(self, state):
        """(state, start_step): resume from the latest checkpoint if any."""
        if not self.cfg.ckpt_dir:
            return state, 0
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return state, 0
        restored, step = ckpt.restore(self.cfg.ckpt_dir, state)
        self.events.append(f"restored checkpoint at step {step}")
        return restored, step + 1

    def maybe_save(self, state, step: int):
        if self.cfg.ckpt_dir and self.cfg.ckpt_every and step > 0 \
                and step % self.cfg.ckpt_every == 0:
            ckpt.save(state, self.cfg.ckpt_dir, step=step, async_=True)

    def finalize(self, state, step: int):
        if self.cfg.ckpt_dir:
            ckpt.save(state, self.cfg.ckpt_dir, step=step)


def remesh(directory: str, like, new_mesh, shardings_fn):
    """Elastic restore: load a checkpoint onto a *different* mesh.

    ``shardings_fn(like, mesh)`` rebuilds the sharding pytree for the
    surviving device set, so a run that lost hosts resumes on what is left.
    """
    shardings = shardings_fn(like, new_mesh)
    return ckpt.restore(directory, like, shardings=shardings)
