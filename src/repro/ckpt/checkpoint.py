"""Sharded, atomic, async checkpointing (mesh-independent layout).

Layout of one checkpoint:

    <dir>/step_<N>/
        manifest.json      {"step": N, "leaves": {path: {shape, dtype}},
                            "hosts": H}
        host<h>.npz        one entry per leaf path: this host's gathered data
    <dir>/LATEST           text file with the newest complete step dir

Writes go to ``step_<N>.tmp`` and are renamed only after everything is
flushed — a torn write can never be picked up by restore (power-fail safe).
Restore is mesh-independent: leaves are re-sharded onto whatever mesh the
restoring job uses, which is what makes *elastic re-mesh* (dist.fault) work.

The optional ``compress_binary`` flag Huffman-compresses binarised weight
tensors in storage (paper technique applied to checkpoints; DESIGN.md §4).
"""

from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np

from repro.core import compression


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    jax.tree_util.tree_map_with_path(
        lambda kp, x: out.setdefault(_path_str(kp), np.asarray(x)), tree)
    return out


def save(tree, directory: str, step: int, *, async_: bool = False,
         compress_binary: bool = False) -> threading.Thread | None:
    """Save a pytree. Returns the writer thread when ``async_``."""
    flat = _flatten(tree)

    def write():
        tmp = os.path.join(directory, f"step_{step}.tmp")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "hosts": 1, "leaves": {}, "compressed": []}
        blobs = {}
        for path, arr in flat.items():
            manifest["leaves"][path] = {"shape": list(arr.shape),
                                        "dtype": str(arr.dtype)}
            if (compress_binary and arr.ndim == 4
                    and arr.dtype in (np.float32, np.float16)
                    and "w3" in path.split("/")[-1]):
                # lossless in the binary domain: no clustering on checkpoints
                # (inference-snapshot feature: latents collapse to sign*scale)
                bits = (arr >= 0).astype(np.uint8)
                ct = compression.compress_conv3x3(bits, cluster=False)
                blobs[path + "#stream"] = ct.stream_words
                blobs[path + "#scale"] = np.abs(arr).mean(
                    axis=tuple(range(1, arr.ndim)))
                blobs[path + "#tables"] = ct.decode_tables()
                blobs[path + "#bits"] = np.asarray([ct.stream_bits])
                manifest["compressed"].append(path)
            else:
                blobs[path] = arr
        np.savez(os.path.join(tmp, "host0.npz"), **blobs)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)                  # atomic publish
        with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
            f.write(f"step_{step}")
        os.replace(os.path.join(directory, "LATEST.tmp"),
                   os.path.join(directory, "LATEST"))

    os.makedirs(directory, exist_ok=True)
    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> int | None:
    marker = os.path.join(directory, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(directory: str, like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or SDS).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    placed shard-by-shard (device_put with sharding), so restore works on a
    different mesh than the one that saved (elastic re-mesh).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    blobs = np.load(os.path.join(d, "host0.npz"))

    leaves_flat: dict[str, np.ndarray] = {}
    for path, meta in manifest["leaves"].items():
        if path in manifest.get("compressed", []):
            from repro.core import bitpack, huffman
            words = blobs[path + "#stream"]
            nbits = int(blobs[path + "#bits"][0])
            shape = tuple(meta["shape"])
            n_seqs = int(np.prod(shape[:2])) if len(shape) == 4 else None
            # rebuild the NodeAssignment from stored tables
            tables = blobs[path + "#tables"]
            assign = _assignment_from_tables(tables)
            seqs = huffman.decode_stream(words, nbits, assign, count=n_seqs)
            bits = bitpack.sequences_to_kernel(
                seqs.reshape(shape[:2]))
            scale = blobs[path + "#scale"].reshape(
                (-1,) + (1,) * (len(shape) - 1))
            leaves_flat[path] = (bits.astype(np.float32) * 2 - 1) * scale
        else:
            leaves_flat[path] = blobs[path]

    paths_like = []
    jax.tree_util.tree_map_with_path(
        lambda kp, x: paths_like.append(_path_str(kp)), like)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    flat_shard = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for path, proto, shd in zip(paths_like, flat_like, flat_shard):
        arr = leaves_flat[path].astype(proto.dtype)
        assert tuple(arr.shape) == tuple(proto.shape), (path, arr.shape)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), step


def _assignment_from_tables(tables_flat: np.ndarray):
    """Reconstruct a NodeAssignment equivalent for decoding from the stored
    160-entry table (escape node needs no table)."""
    from repro.core import huffman
    node_of = np.full(512, 3, np.int32)
    index_of = np.arange(512, dtype=np.int32)
    t0, t1, t2 = tables_flat[:32], tables_flat[32:96], tables_flat[96:160]
    for n, t in enumerate((t0, t1, t2)):
        node_of[t] = n
        index_of[t] = np.arange(len(t))
    return huffman.NodeAssignment(
        node_of, index_of,
        (t0.astype(np.uint16), t1.astype(np.uint16), t2.astype(np.uint16)))
