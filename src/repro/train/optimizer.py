"""AdamW + LR schedules, BNN-aware (no framework dependency).

BNN latent weights (paper §II-A): binarised layers train on full-precision
latent weights via STE — the optimizer is oblivious, but ``clip_latent``
keeps latents in [-1.5, 1.5] so signs keep flipping (standard BNN practice).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_latent: float = 0.0          # >0 for BNN latent weights


def lr_schedule(oc: OptConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(oc.warmup_steps, 1)
        t = (step - oc.warmup_steps) / jnp.maximum(
            oc.total_steps - oc.warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)
    return fn


def init_state(params: Any) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"step": jnp.zeros((), jnp.int32),
            "mu": zeros(params), "nu": zeros(params)}


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state, oc: OptConfig):
    """One AdamW step -> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(oc)(step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if oc.grad_clip else 1.0
    b1, b2 = oc.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + oc.eps) + \
            oc.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        if oc.clip_latent:
            new_p = jnp.clip(new_p, -oc.clip_latent, oc.clip_latent)
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"step": step,
                 "mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out])}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
