"""ReActNet (Liu et al., ECCV 2020) — the paper's baseline BNN.

MobileNetV1-shaped binary network: an 8-bit full-precision stem conv, 13
basic blocks (binary 3x3 + binary 1x1, each wrapped with RSign / RPReLU and
BatchNorm-style normalisation), global pooling and an 8-bit FC head —
matching the paper's Table I storage/precision breakdown.

Each binary conv runs in one of three selectable modes:
  * "ste"        — float sign/STE path (training; pure jnp)
  * "packed"     — xnor/popcount Pallas kernel on packed bits (inference)
  * "compressed" — Huffman-compressed weights, decode fused into the conv
                   kernel (the paper's contribution end-to-end)

Weight layout: (Cout, Cin, 3, 3) — the channel dim is the paper's 9-bit
*bit sequence* axis.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binarize import ste_sign
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ReActNetConfig:
    name: str = "reactnet"
    num_classes: int = 1000
    in_channels: int = 3
    width: int = 32                  # stem width (ReActNet-A: 32)
    # (out_mult, stride) per basic block; ReActNet-A MobileNet schedule
    blocks: tuple = ((2, 1), (2, 2), (1, 1), (2, 2), (1, 1), (2, 2),
                     (1, 1), (1, 1), (1, 1), (1, 1), (1, 1), (2, 2), (1, 1))
    image_size: int = 224
    conv_mode: str = "ste"           # ste | packed | compressed
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


CONFIG = ReActNetConfig()


# ---------------------------------------------------------------------------
# layer pieces
# ---------------------------------------------------------------------------

def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def _bn(p, x, train: bool):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
    else:
        mean, var = p["mean"], p["var"]
    inv = jax.lax.rsqrt(var + 1e-5)
    return (x - mean) * inv * p["scale"] + p["bias"]


def _rsign_init(c):
    return {"beta": jnp.zeros((c,))}


def _rsign(p, x):
    """ReAct-Sign: learnable per-channel shift before binarisation."""
    return ste_sign(x - p["beta"])


def _rprelu_init(c):
    return {"gamma": jnp.zeros((c,)), "zeta": jnp.zeros((c,)),
            "slope": jnp.full((c,), 0.25)}


def _rprelu(p, x):
    """ReAct-PReLU: y = PReLU(x - gamma) + zeta with learnable shifts."""
    xs = x - p["gamma"]
    return jnp.where(xs >= 0, xs, xs * p["slope"]) + p["zeta"]


def _binary_conv_apply(w, x, stride: int, mode: str, compressed=None):
    """x is already binarised (+-1).  Returns (N, Ho, Wo, Cout) f32."""
    alpha = jnp.mean(jnp.abs(jax.lax.stop_gradient(w)), axis=(1, 2, 3))
    if mode == "ste":
        wb = ste_sign(w)
        out = jax.lax.conv_general_dilated(
            jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), constant_values=-1.0),
            jnp.transpose(wb, (2, 3, 1, 0)), (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    elif mode == "packed":
        out = ops.binary_conv3x3(x, w, stride=stride)
    elif mode == "compressed":
        words, tables, meta = compressed
        out = ops.compressed_binary_conv3x3(
            x, words, tables, cin=w.shape[1], cout=w.shape[0], stride=stride)
    else:  # pragma: no cover
        raise ValueError(mode)
    return out * alpha


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_params(cfg: ReActNetConfig, key) -> dict:
    keys = iter(jax.random.split(key, 4 + 4 * len(cfg.blocks)))
    c = cfg.width
    params: dict = {
        "stem": {"w": jax.random.normal(next(keys), (c, cfg.in_channels, 3, 3))
                 * (9 * cfg.in_channels) ** -0.5,
                 "bn": _bn_init(c)},
        "blocks": [],
    }
    for mult, _stride in cfg.blocks:
        cout = c * mult
        blk = {
            "rsign1": _rsign_init(c),
            "w3": jax.random.normal(next(keys), (c, c, 3, 3)) * (9 * c) ** -0.5,
            "bn1": _bn_init(c),
            "rprelu1": _rprelu_init(c),
            "rsign2": _rsign_init(c),
            "w1": jax.random.normal(next(keys), (cout, c, 1, 1)) * c ** -0.5,
            "bn2": _bn_init(cout),
            "rprelu2": _rprelu_init(cout),
        }
        params["blocks"].append(blk)
        c = cout
    params["head"] = {
        "w": jax.random.normal(next(keys), (c, cfg.num_classes)) * c ** -0.5,
        "b": jnp.zeros((cfg.num_classes,)),
    }
    return params


def _block_apply(blk, x, mult: int, stride: int, mode: str, train: bool,
                 compressed=None):
    c_in = x.shape[-1]
    # --- 3x3 binary conv sub-layer (the paper's compression target) -------
    xb = _rsign(blk["rsign1"], x)
    y = _binary_conv_apply(blk["w3"], xb, stride, mode, compressed)
    y = _bn(blk["bn1"], y, train)
    if stride == 2:
        short = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    else:
        short = x
    y = _rprelu(blk["rprelu1"], y + short)

    # --- 1x1 binary conv sub-layer (as a binary GEMM) ---------------------
    yb = _rsign(blk["rsign2"], y)
    w1 = blk["w1"][:, :, 0, 0]                       # (Cout, Cin)
    alpha = jnp.mean(jnp.abs(jax.lax.stop_gradient(w1)), axis=1)
    n, h, w_, _ = yb.shape
    if mode == "ste":
        z = (yb.reshape(-1, c_in) @ ste_sign(w1).T).reshape(n, h, w_, -1)
    else:
        z = ops.binary_matmul(yb.reshape(-1, c_in), w1).reshape(n, h, w_, -1)
    z = z * alpha
    z = _bn(blk["bn2"], z, train)
    if z.shape[-1] == y.shape[-1]:
        z = z + y
    else:                                            # channel duplication
        z = z + jnp.concatenate([y] * mult, axis=-1)
    return _rprelu(blk["rprelu2"], z)


def forward(cfg: ReActNetConfig, params, images, *, train: bool = False,
            compressed: list | None = None):
    """images (N, H, W, 3) -> logits (N, num_classes)."""
    x = jax.lax.conv_general_dilated(
        images, jnp.transpose(params["stem"]["w"], (2, 3, 1, 0)),
        (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = _bn(params["stem"]["bn"], x, train)
    for i, ((mult, stride), blk) in enumerate(zip(cfg.blocks,
                                                  params["blocks"])):
        comp = compressed[i] if compressed is not None else None
        x = _block_apply(blk, x, mult, stride, cfg.conv_mode, train, comp)
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def loss_fn(cfg, params, batch, *, train: bool = True):
    logits = forward(cfg, params, batch["images"], train=train)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# offline compression of a trained model (paper pipeline)
# ---------------------------------------------------------------------------

def binary_weight_bits(params) -> dict[str, np.ndarray]:
    """name -> {0,1} bit tensors of every binary conv (3x3 and 1x1)."""
    out = {}
    for i, blk in enumerate(params["blocks"]):
        out[f"block{i}/w3"] = np.asarray(blk["w3"] >= 0, dtype=np.uint8)
        out[f"block{i}/w1"] = np.asarray(
            blk["w1"][:, :, 0, 0] >= 0, dtype=np.uint8)
    return out


def prepare_compressed(params, cluster: bool = True, gather: str = "onehot"):
    """Per-block fused-kernel operands for conv_mode="compressed"."""
    comp = []
    for blk in params["blocks"]:
        w_bits = np.asarray(blk["w3"] >= 0, dtype=np.uint8)
        comp.append(ops.prepare_compressed_conv(
            w_bits, cluster=cluster, gather=gather))
    return comp


def fp_bits(cfg: ReActNetConfig, params) -> int:
    """Bits of the non-binary remainder (8-bit stem + head, fp32 BN/PReLU),
    per the paper's Table I quantisation choices."""
    stem = params["stem"]["w"].size * 8
    head = (params["head"]["w"].size + params["head"]["b"].size) * 8
    other = 0
    for blk in params["blocks"]:
        for k in ("rsign1", "rsign2", "rprelu1", "rprelu2", "bn1", "bn2"):
            other += sum(v.size for v in blk[k].values()) * 32
    other += sum(v.size for v in params["stem"]["bn"].values()) * 32
    return stem + head + other
