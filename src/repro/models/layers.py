"""Core layer substrate: init helpers, norms, RoPE, MLPs, embeddings.

Functional style: params are plain pytrees (dicts); every layer is
``f(params, x, ...) -> y``.  No framework dependency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.binarize import binarize_weights
from repro.kernels import ref as kref


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def linear(w: jax.Array, x: jax.Array) -> jax.Array:
    return x @ w


def binary_linear(w: jax.Array, x: jax.Array) -> jax.Array:
    """BNN linear (paper-technique integration): sign(x) @ sign(w) * alpha.

    Uses the STE binariser so the layer stays trainable; on TPU the packed
    xnor/popcount kernel implements the same contraction (kernels.ops).
    """
    wb = binarize_weights(w.T).T          # per-output-channel scale
    xb = jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)
    return xb @ wb


def rms_norm(g: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + g.astype(jnp.float32))
            ).astype(x.dtype)


def rms_norm_init(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap else x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                            # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k2, d_ff, d_model, dtype)}
    if act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k1, d_model, d_ff, dtype)
        p["up"] = dense_init(k3, d_model, d_ff, dtype)
    else:
        p["up"] = dense_init(k1, d_model, d_ff, dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, act: str,
              binarized: bool = False) -> jax.Array:
    lin = binary_linear if binarized else linear
    if act == "swiglu":
        return lin(p["down"], jax.nn.silu(lin(p["gate"], x)) * lin(p["up"], x))
    if act == "geglu":
        return lin(p["down"],
                   jax.nn.gelu(lin(p["gate"], x), approximate=True)
                   * lin(p["up"], x))
    return lin(p["down"], jax.nn.gelu(lin(p["up"], x), approximate=True))


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over all positions. logits (..., V) f32-accumulated."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_cross_entropy(x: jax.Array, head: jax.Array, labels: jax.Array,
                          *, softcap_val: float = 0.0,
                          chunk: int = 256) -> jax.Array:
    """CE of (x @ head) without materialising full (B, S, V) logits.

    Scans over sequence chunks with per-chunk remat: live logits are one
    (B, chunk, V) block; the head gradient accumulates across chunks.
    (EXPERIMENTS.md §Perf iter 3 — the (B,S,V) block was the largest buffer
    of every train cell: 6.3 GB/device on gemma2 train_4k.)
    """
    import math as _math
    b, s, d = x.shape
    chunk = _math.gcd(s, chunk)
    nc = s // chunk
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    # NOTE (§Perf gemma2 iter G6, REFUTED): gathering the gold logit from
    # the head (take(head.T, labels) + dot) instead of take_along_axis on
    # the logits was predicted to remove the (B, chunk, V) scatter in the
    # backward; measured WORSE (+0.2s memory term) — its backward scatters
    # into the full (D, V) head per chunk instead.  Kept the logits gather.
    def step(tot, inp):
        xc, lc = inp
        logits = (xc @ head).astype(jnp.float32)
        logits = softcap(logits, softcap_val)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            (xs, ls))
    return total / (b * s)
