"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Per the assignment brief the audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, Se, D); the encoder is the bidirectional
transformer stack, the decoder is causal with cross-attention.  Positional
encoding deviates from Whisper's sinusoids — the shared substrate's RoPE is
used (documented in DESIGN.md; irrelevant to the systems claims).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import chunked_cross_entropy, embed_init, \
    rms_norm, rms_norm_init, softcap
from repro.models.transformer import _stack, block_apply, \
    block_cache_spec, block_init, remat_wrap


def init_params(cfg, key) -> dict:
    dtype = cfg.jnp_dtype
    k_embed, k_enc, k_dec, k_extra = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "enc_scan": _stack([{"b0": block_init("bidir", cfg, k, dtype)}
                            for k in enc_keys]),
        "enc_norm": rms_norm_init(cfg.d_model, dtype),
        "scan": _stack([{"b0": block_init("dec", cfg, k, dtype)}
                        for k in dec_keys]),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }


def encode(cfg, params, frame_embeds: jax.Array) -> jax.Array:
    x = constrain(frame_embeds.astype(cfg.jnp_dtype), "batch", None, None)

    def body(x, layer_params):
        x, _, _ = block_apply("bidir", cfg, layer_params["b0"], x)
        return constrain(x, "batch", None, None), None

    body_fn = remat_wrap(cfg, body)
    x, _ = jax.lax.scan(body_fn, x, params["enc_scan"])
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _decoder_hidden(cfg, params, tokens, frame_embeds):
    enc_out = encode(cfg, params, frame_embeds)
    x = constrain(params["embed"][tokens], "batch", "model", None)

    def body(x, layer_params):
        x, _, _ = block_apply("dec", cfg, layer_params["b0"], x,
                              enc_out=enc_out)
        return constrain(x, "batch", "model", None), None

    body_fn = remat_wrap(cfg, body)
    x, _ = jax.lax.scan(body_fn, x, params["scan"])
    return rms_norm(params["final_norm"], x, cfg.norm_eps)


def forward(cfg, params, tokens, frame_embeds):
    """Training forward -> (logits, aux=0)."""
    x = _decoder_hidden(cfg, params, tokens, frame_embeds)
    logits = softcap((x @ params["embed"].T).astype(jnp.float32),
                     cfg.final_logit_softcap)
    return (constrain(logits, "batch", "model", None),
            jnp.zeros((), jnp.float32))


def loss_fn(cfg, params, batch) -> jax.Array:
    hidden = _decoder_hidden(cfg, params, batch["tokens"],
                             batch["frame_embeds"])
    return chunked_cross_entropy(hidden, params["embed"].T, batch["labels"],
                                 softcap_val=cfg.final_logit_softcap)


def init_cache_specs(cfg, batch: int, max_len: int):
    one = {"b0": block_cache_spec("dec", cfg, batch, max_len)}
    return {"scan": jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_layers, *s.shape), s.dtype),
        one)}


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  init_cache_specs(cfg, batch, max_len))


def prefill(cfg, params, tokens, cache, frame_embeds):
    enc_out = encode(cfg, params, frame_embeds)
    x = constrain(params["embed"][tokens], "batch", None, None)

    def body(x, xs):
        layer_params, layer_cache = xs
        x, nc, _ = block_apply("dec", cfg, layer_params["b0"], x,
                               cache=layer_cache["b0"], enc_out=enc_out)
        return x, {"b0": nc}

    x, scan_cache = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = softcap((x @ params["embed"].T).astype(jnp.float32),
                     cfg.final_logit_softcap)
    return logits, {"scan": scan_cache}


def decode_step(cfg, params, cache, tokens, pos):
    x = constrain(params["embed"][tokens], "batch", None, None)

    def body(x, xs):
        layer_params, layer_cache = xs
        x, nc, _ = block_apply("dec", cfg, layer_params["b0"], x,
                               cache=layer_cache["b0"], pos=pos)
        return x, {"b0": nc}

    x, scan_cache = jax.lax.scan(body, x, (params["scan"], cache["scan"]))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = softcap((x @ params["embed"].T).astype(jnp.float32),
                     cfg.final_logit_softcap)
    return constrain(logits, "batch", None, "model"), {"scan": scan_cache}
