"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
linear first-order recurrence -> evaluated with ``lax.associative_scan``
(log-depth) for train/prefill and a single fused update for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import dense_init

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def rglru_init(key, cfg, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, w, dtype),
        "w_gate": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (4, w)) * 0.1).astype(dtype),
        "w_r": dense_init(ks[3], w, w, dtype),
        "w_i": dense_init(ks[4], w, w, dtype),
        # Lambda init so that a^c in (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, w) ** (-1.0 / _C) - 1.0)
        ).astype(jnp.float32) * -1.0,
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _conv(x, conv_w, state=None, q_lens=None):
    k = conv_w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
           if state is None else state.astype(x.dtype))
    full = jnp.concatenate([pad, x], axis=1)
    out = sum(full[:, i:i + x.shape[1]] * conv_w[i] for i in range(k))
    if q_lens is None:
        new_state = full[:, -(k - 1):]
    else:
        # ragged: read each lane's carried-out state at its own valid
        # length (q_lens[b] == 0 returns the incoming state unchanged)
        idx = (jnp.asarray(q_lens, jnp.int32)[:, None]
               + jnp.arange(k - 1)[None, :])
        new_state = jnp.take_along_axis(full, idx[..., None], axis=1)
    return out, new_state


def _gates(p, xw):
    r = jax.nn.sigmoid(xw.astype(jnp.float32) @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xw.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # (B, S, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xw.astype(jnp.float32))
    return a, gated


def rglru_apply(p: dict, x: jax.Array, cfg, *, cache=None, pos=None,
                q_lens=None):
    """cache = {"conv": (B, 3, W), "h": (B, W)}.

    With ``cache`` and ``pos`` the recurrence *resumes* from the cached
    state (chunked prefill / speculative verification): the scan's prefix
    products fold the incoming ``cache["h"]`` into every position via
    ``h_t = h_scan_t + (prod a_1..a_t) * h_0``.  Ragged ``q_lens`` masks
    padded positions to the identity update (``a = 1``, input 0), so a
    ``q_lens[b] == 0`` lane is an exact no-op on its cache.
    """
    b, s, _ = x.shape
    decode = cache is not None and s == 1 and q_lens is None
    resume = cache is not None and pos is not None and not decode

    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    xw = x @ p["w_x"]
    xw = constrain(xw, "batch", None, "model")   # recurrence shards on width
    xw, new_conv = _conv(xw, p["conv_w"],
                         cache["conv"] if (decode or resume) else None,
                         q_lens=q_lens)
    a, gated = _gates(p, xw)
    if q_lens is not None:
        valid = (jnp.arange(s)[None, :, None] <
                 jnp.asarray(q_lens, jnp.int32)[:, None, None])  # (B, S, 1)
        a = jnp.where(valid, a, 1.0)
        gated = jnp.where(valid, gated, 0.0)

    if decode:
        h = cache["h"] * a[:, 0] + gated[:, 0]
        y = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, b1 * a2 + b2

        a_sc, h_sc = jax.lax.associative_scan(combine, (a, gated), axis=1)
        if resume:
            h_sc = h_sc + a_sc * cache["h"].astype(jnp.float32)[:, None]
        y = h_sc
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                         "h": h_sc[:, -1]}
    y = (y.astype(x.dtype) * gate) @ p["w_out"]
    return y, new_cache


def rglru_cache_spec(cfg, batch: int):
    w = cfg.lru_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, w), cfg.jnp_dtype),
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }
