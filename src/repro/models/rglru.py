"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
linear first-order recurrence -> evaluated with ``lax.associative_scan``
(log-depth) for train/prefill and a single fused update for decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import dense_init

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def rglru_init(key, cfg, dtype) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, w, dtype),
        "w_gate": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (4, w)) * 0.1).astype(dtype),
        "w_r": dense_init(ks[3], w, w, dtype),
        "w_i": dense_init(ks[4], w, w, dtype),
        # Lambda init so that a^c in (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, w) ** (-1.0 / _C) - 1.0)
        ).astype(jnp.float32) * -1.0,
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _conv(x, conv_w, state=None):
    k = conv_w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
           if state is None else state)
    full = jnp.concatenate([pad, x], axis=1)
    out = sum(full[:, i:i + x.shape[1]] * conv_w[i] for i in range(k))
    return out, full[:, -(k - 1):]


def _gates(p, xw):
    r = jax.nn.sigmoid(xw.astype(jnp.float32) @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xw.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # (B, S, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xw.astype(jnp.float32))
    return a, gated


def rglru_apply(p: dict, x: jax.Array, cfg, *, cache=None, pos=None):
    """cache = {"conv": (B, 3, W), "h": (B, W)}."""
    b, s, _ = x.shape
    decode = cache is not None and s == 1
    if cache is not None and pos is not None and s > 1:
        raise NotImplementedError(
            "chunked prefill is not supported for RG-LRU blocks (the "
            "recurrence cannot resume from a cached state mid-prompt yet)")

    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    xw = x @ p["w_x"]
    xw = constrain(xw, "batch", None, "model")   # recurrence shards on width
    xw, new_conv = _conv(xw, p["conv_w"], cache["conv"] if decode else None)
    a, gated = _gates(p, xw)

    if decode:
        h = cache["h"] * a[:, 0] + gated[:, 0]
        y = h[:, None]
        new_cache = {"conv": new_conv, "h": h}
    else:
        def combine(left, right):
            a1, b1 = left
            a2, b2 = right
            return a1 * a2, b1 * a2 + b2

        a_sc, h_sc = jax.lax.associative_scan(combine, (a, gated), axis=1)
        y = h_sc
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                         "h": h_sc[:, -1]}
    y = (y.astype(x.dtype) * gate) @ p["w_out"]
    return y, new_cache


def rglru_cache_spec(cfg, batch: int):
    w = cfg.lru_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, w), cfg.jnp_dtype),
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }
