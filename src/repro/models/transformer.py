"""Decoder-only LM composition: block dispatch + scan-over-layers stacking.

A config's layer stack is ``prefix_kinds + scan_pattern * scan_repeats +
suffix_kinds``.  The repeated pattern is stacked parameter-wise and executed
with ``lax.scan`` over super-blocks (one super-block = one pass through the
pattern) so HLO size and compile time are independent of depth; prefix and
suffix layers are unrolled.  Heterogeneous patterns (gemma2's local/global
alternation, recurrentgemma's rec/rec/attn triple) are naturally supported
because the super-block pytree is uniform across repeats.

Block kinds: attn | swa | local | global | attn_local | mla_dense | mla_moe |
swa_moe | moe | ssm | rglru | bidir (encoder) | dec (decoder w/ cross-attn).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (chunked_cross_entropy, cross_entropy,
                                 embed_init, mlp_apply, mlp_init, rms_norm,
                                 rms_norm_init, softcap)

ATTN_KINDS = ("attn", "swa", "local", "global", "attn_local", "bidir")
MOE_KINDS = ("swa_moe", "mla_moe", "moe")
MLA_KINDS = ("mla_dense", "mla_moe")


def remat_wrap(cfg, fn):
    """Wrap a scan body in jax.checkpoint per the config's remat policy."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _attn_kind(kind: str) -> str:
    """Map block kind -> attention variant."""
    return {"swa": "swa", "swa_moe": "swa", "local": "local",
            "attn_local": "local", "bidir": "bidir"}.get(kind, "attn")


# ---------------------------------------------------------------------------
# block init / apply
# ---------------------------------------------------------------------------

def block_init(kind: str, cfg, key, dtype) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"ln1": rms_norm_init(d, dtype)}
    if kind == "ssm":
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
        return p
    if kind == "rglru":
        p["mixer"] = rglru_mod.rglru_init(ks[0], cfg, dtype)
    elif kind in MLA_KINDS:
        p["attn"] = attn.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    if kind == "dec":
        p["ln_cross"] = rms_norm_init(d, dtype)
        p["cross"] = attn.cross_attn_init(ks[2], cfg, dtype)
    p["ln2"] = rms_norm_init(d, dtype)
    if kind in MOE_KINDS:
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_act, dtype)
    if cfg.post_norms:
        p["post_ln1"] = rms_norm_init(d, dtype)
        p["post_ln2"] = rms_norm_init(d, dtype)
    return p


def block_apply(kind: str, cfg, p: dict, x: jax.Array, *,
                cache=None, pos=None, prefix_len: int = 0, enc_out=None,
                paged=None, q_lens=None, scales=None, kv_quant=False):
    """-> (x, new_cache, aux_loss); with ``scales`` ->
    (x, new_cache, new_scales, aux_loss).

    ``paged`` (an ``attention.PagedContext``) is only passed on mixed /
    decode steps of the ``pallas_paged`` backend, and only for blocks
    whose cache leaves are page pools; lane-backed blocks receive
    ``paged=None`` and run the gathered reference path.  ``q_lens``
    carries the ragged per-slot token counts of a mixed step (None =
    every token is real).  ``scales`` carries this block's
    ``kv_codec="cluster"`` scale pools (same keys as the cache leaves,
    ``(n_pages, page)`` f32 each) and implies the cache leaves hold int8
    codes; only attention blocks can receive it.
    """
    aux = jnp.zeros((), jnp.float32)
    new_scales = None
    h = rms_norm(p["ln1"], x, cfg.norm_eps)

    if kind == "ssm":
        y, new_cache = ssm_mod.ssm_apply(p["mixer"], h, cfg,
                                         cache=cache, pos=pos,
                                         q_lens=q_lens)
        return x + y, new_cache, aux

    if kind == "rglru":
        y, new_cache = rglru_mod.rglru_apply(p["mixer"], h, cfg,
                                             cache=cache, pos=pos,
                                             q_lens=q_lens)
    elif kind in MLA_KINDS:
        res = attn.mla_apply(p["attn"], h, cfg, cache=cache, pos=pos,
                             paged=paged, q_lens=q_lens, scales=scales,
                             kv_quant=kv_quant)
        if scales is not None:
            y, new_cache, new_scales = res
        else:
            y, new_cache = res
    else:
        self_cache = cache.get("self") if isinstance(cache, dict) and \
            "self" in (cache or {}) else cache
        res = attn.attn_apply(
            p["attn"], h, cfg, kind=_attn_kind(kind), cache=self_cache,
            pos=pos, prefix_len=prefix_len, paged=paged, q_lens=q_lens,
            scales=scales, kv_quant=kv_quant)
        if scales is not None:
            y, new_cache, new_scales = res
        else:
            y, new_cache = res
    if cfg.post_norms:
        y = rms_norm(p["post_ln1"], y, cfg.norm_eps)
    x = x + y

    if kind == "dec":                     # cross-attention sub-layer
        hc = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        # cached cross-K/V is only valid for decode; prefill recomputes it
        # from enc_out (the initial cache is zeros)
        decode_mode = x.shape[1] == 1 and enc_out is None
        enc_kv = cache.get("cross") if (decode_mode and
                                        isinstance(cache, dict)) else None
        yc, cross_kv = attn.cross_attn_apply(p["cross"], hc, cfg,
                                             enc_kv=enc_kv, enc_out=enc_out)
        x = x + yc
        if cache is not None:
            new_cache = {"self": new_cache, "cross": cross_kv}

    if "moe" in p or "mlp" in p:
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        if kind in MOE_KINDS:
            y2, aux = moe_mod.moe_apply(p["moe"], h2, cfg)
        else:
            y2 = mlp_apply(p["mlp"], h2, cfg.mlp_act,
                           binarized=cfg.binarize_mlp)
        if cfg.post_norms:
            y2 = rms_norm(p["post_ln2"], y2, cfg.norm_eps)
        x = x + y2
    if scales is not None:
        return x, new_cache, new_scales, aux
    return x, new_cache, aux


def block_cache_spec(kind: str, cfg, batch: int, max_len: int):
    if kind == "ssm":
        return ssm_mod.ssm_cache_spec(cfg, batch)
    if kind == "rglru":
        return rglru_mod.rglru_cache_spec(cfg, batch)
    if kind in MLA_KINDS:
        return attn.mla_cache_spec(cfg, batch, max_len)
    if kind == "dec":
        return {"self": attn.attn_cache_spec(cfg, "attn", batch, max_len),
                "cross": {"k": jax.ShapeDtypeStruct(
                              (batch, cfg.encoder_seq, cfg.num_kv_heads,
                               cfg.head_dim), cfg.jnp_dtype),
                          "v": jax.ShapeDtypeStruct(
                              (batch, cfg.encoder_seq, cfg.num_kv_heads,
                               cfg.head_dim), cfg.jnp_dtype)}}
    return attn.attn_cache_spec(cfg, _attn_kind(kind), batch, max_len)


# ---------------------------------------------------------------------------
# parameter / cache trees
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg, key) -> dict:
    dtype = cfg.jnp_dtype
    keys = jax.random.split(key, 4 + len(cfg.prefix_kinds)
                            + cfg.scan_repeats + len(cfg.suffix_kinds))
    ki = iter(range(len(keys)))
    params: dict = {
        "embed": embed_init(keys[next(ki)], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(
            keys[next(ki)], cfg.vocab_size, cfg.d_model, dtype).T
    params["prefix"] = [
        block_init(k, cfg, keys[next(ki)], dtype) for k in cfg.prefix_kinds]
    reps = []
    for _ in range(cfg.scan_repeats):
        kk = jax.random.split(keys[next(ki)], len(cfg.scan_pattern))
        reps.append({f"b{i}": block_init(k, cfg, kk[i], dtype)
                     for i, k in enumerate(cfg.scan_pattern)})
    params["scan"] = _stack(reps) if reps else {}
    params["suffix"] = [
        block_init(k, cfg, keys[next(ki)], dtype) for k in cfg.suffix_kinds]
    return params


def init_cache_specs(cfg, batch: int, max_len: int):
    cache: dict = {
        "prefix": [block_cache_spec(k, cfg, batch, max_len)
                   for k in cfg.prefix_kinds],
        "suffix": [block_cache_spec(k, cfg, batch, max_len)
                   for k in cfg.suffix_kinds],
    }
    one = {f"b{i}": block_cache_spec(k, cfg, batch, max_len)
           for i, k in enumerate(cfg.scan_pattern)}
    cache["scan"] = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.scan_repeats, *s.shape), s.dtype),
        one) if cfg.scan_repeats else {}
    return cache


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        init_cache_specs(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens, vision_embeds=None):
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    # sequence-parallel residual stream: tokens sharded over "model"
    return constrain(x, "batch", "model", None)


def _unembed(cfg, params, x):
    """x: final-norm'd hidden -> softcapped f32 logits."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = softcap(logits, cfg.final_logit_softcap)
    # logits stay sequence-sharded: (B, S/model, V) — 16x less live memory
    # than vocab-full-per-device, and CE is fully local in S
    return constrain(logits.astype(jnp.float32), "batch", "model", None)


def backbone(cfg, params, tokens, *, vision_embeds=None):
    """Embed + layer stack + final norm -> (hidden (B, S*, D), aux loss)."""
    prefix_len = vision_embeds.shape[1] if vision_embeds is not None else 0
    x = _embed(cfg, params, tokens, vision_embeds)
    aux_total = jnp.zeros((), jnp.float32)

    for kind, p in zip(cfg.prefix_kinds, params["prefix"]):
        x, _, aux = block_apply(kind, cfg, p, x, prefix_len=prefix_len)
        aux_total += aux

    if cfg.scan_repeats:
        def body(carry, layer_params):
            x, aux_sum = carry
            for i, kind in enumerate(cfg.scan_pattern):
                x, _, aux = block_apply(kind, cfg, layer_params[f"b{i}"], x,
                                        prefix_len=prefix_len)
                aux_sum += aux
            x = constrain(x, "batch", "model", None)
            return (x, aux_sum), None

        (x, aux_total), _ = jax.lax.scan(remat_wrap(cfg, body),
                                         (x, aux_total), params["scan"])

    for kind, p in zip(cfg.suffix_kinds, params["suffix"]):
        x, _, aux = block_apply(kind, cfg, p, x, prefix_len=prefix_len)
        aux_total += aux
    return rms_norm(params["final_norm"], x, cfg.norm_eps), aux_total


def forward(cfg, params, tokens, *, vision_embeds=None):
    """Training/scoring forward -> (logits (B, S*, V) f32, aux loss)."""
    x, aux_total = backbone(cfg, params, tokens,
                            vision_embeds=vision_embeds)
    return _unembed(cfg, params, x), aux_total


def loss_fn(cfg, params, batch) -> jax.Array:
    hidden, aux = backbone(cfg, params, batch["tokens"],
                           vision_embeds=batch.get("vision_embeds"))
    if batch.get("vision_embeds") is not None:
        hidden = hidden[:, batch["vision_embeds"].shape[1]:]
    hidden = constrain(hidden, "batch", "model", None)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ce = chunked_cross_entropy(hidden, head, batch["labels"],
                               softcap_val=cfg.final_logit_softcap)
    return ce + 0.01 * aux


def _run_stack(cfg, params, cache, x, *, pos=None, prefix_len: int = 0,
               flags=None, ctx=None, q_lens=None, scales=None,
               kv_quant=False):
    """One pass through prefix + scan + suffix blocks, threading the cache.

    The single block walker behind :func:`prefill`,
    :func:`prefill_chunk`, :func:`decode_step`, and :func:`mixed_step` —
    they differ only in how ``x`` is embedded, which positions are
    attached, and which logits are kept.  ``flags``/``ctx`` carry the
    per-leaf pageability mask + ``attention.PagedContext`` of a paged
    mixed step (None = gathered/lane serving); ``q_lens`` the ragged
    per-slot token counts.  ``scales`` is the ``kv_codec="cluster"``
    scale-pool tree mirroring ``cache``'s block structure (None at
    non-pageable blocks).

    Returns ``(x, new_cache, new_scales)``; ``new_scales`` is None
    unless ``scales`` was passed.
    """
    def block_ctx(f):
        if f is None:
            return None
        leaves = jax.tree_util.tree_leaves(f)
        assert all(leaves) or not any(leaves), \
            "mixed paged/lane cache leaves within one block"
        return ctx if leaves and all(leaves) else None

    def norm_sc(b):
        # a block's scales subtree is "real" iff any leaf is an array;
        # lane-backed blocks carry per-leaf Nones (the canonical scale
        # tree mirrors the cache treedef position-for-position) and run
        # without scales
        if b is None:
            return None
        flat = jax.tree_util.tree_flatten(
            b, is_leaf=lambda v: v is None)[0]
        return b if any(v is not None for v in flat) else None

    def apply(x, kind, p, c, pg, sc):
        # normalise block_apply's with/without-scales return arity
        if sc is None:
            x, nc, _ = block_apply(kind, cfg, p, x, cache=c, pos=pos,
                                   prefix_len=prefix_len, paged=pg,
                                   q_lens=q_lens, kv_quant=kv_quant)
            return x, nc, None
        x, nc, nsc, _ = block_apply(kind, cfg, p, x, cache=c, pos=pos,
                                    prefix_len=prefix_len, paged=pg,
                                    q_lens=q_lens, scales=sc,
                                    kv_quant=kv_quant)
        return x, nc, nsc

    new_cache = {"prefix": [], "suffix": []}
    new_scales = None if scales is None else {"prefix": [], "suffix": []}
    for i, (kind, p, c) in enumerate(zip(cfg.prefix_kinds,
                                         params["prefix"],
                                         cache["prefix"])):
        sc_blk = scales["prefix"][i] if scales is not None else None
        x, nc, nsc = apply(
            x, kind, p, c,
            block_ctx(flags["prefix"][i] if flags else None),
            norm_sc(sc_blk))
        new_cache["prefix"].append(nc)
        if new_scales is not None:
            new_scales["prefix"].append(nsc if nsc is not None else sc_blk)

    if cfg.scan_repeats:
        pgs = [block_ctx(flags["scan"][f"b{i}"] if flags else None)
               for i in range(len(cfg.scan_pattern))]

        def body(x, xs):
            layer_params, layer_cache, layer_scales = xs
            ncs, nscs = {}, {}
            for i, kind in enumerate(cfg.scan_pattern):
                sc_blk = (layer_scales[f"b{i}"]
                          if layer_scales is not None else None)
                x, nc, nsc = apply(
                    x, kind, layer_params[f"b{i}"], layer_cache[f"b{i}"],
                    pgs[i], norm_sc(sc_blk))
                ncs[f"b{i}"] = nc
                nscs[f"b{i}"] = nsc if nsc is not None else sc_blk
            return x, (ncs, nscs)

        x, (scan_cache, scan_scales) = jax.lax.scan(
            body, x, (params["scan"], cache["scan"],
                      scales["scan"] if scales is not None else None))
        new_cache["scan"] = scan_cache
        if new_scales is not None:
            new_scales["scan"] = scan_scales
    else:
        new_cache["scan"] = {}
        if new_scales is not None:
            new_scales["scan"] = {}

    for i, (kind, p, c) in enumerate(zip(cfg.suffix_kinds,
                                         params["suffix"],
                                         cache["suffix"])):
        sc_blk = scales["suffix"][i] if scales is not None else None
        x, nc, nsc = apply(
            x, kind, p, c,
            block_ctx(flags["suffix"][i] if flags else None),
            norm_sc(sc_blk))
        new_cache["suffix"].append(nc)
        if new_scales is not None:
            new_scales["suffix"].append(nsc if nsc is not None else sc_blk)
    return x, new_cache, new_scales


def prefill(cfg, params, tokens, cache, *, vision_embeds=None):
    """Run the full prompt, returning (last-token logits, filled cache)."""
    prefix_len = vision_embeds.shape[1] if vision_embeds is not None else 0
    x = _embed(cfg, params, tokens, vision_embeds)
    x, new_cache, _ = _run_stack(cfg, params, cache, x,
                                 prefix_len=prefix_len)
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = _unembed(cfg, params, x)
    return logits, new_cache


def _embed_step(cfg, params, tokens):
    """Embed serving-step tokens (no vision splice, lane-sharded)."""
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(x, "batch", None, None)


def prefill_chunk(cfg, params, cache, tokens, pos, *, kv_quant=False):
    """One prefill chunk: ``tokens`` (B, S) at absolute positions
    pos..pos+S-1 against a partially filled cache -> (last-position logits
    (B, 1, V), new cache).

    Splitting a prompt into chunks and feeding them here in order is
    mathematically identical to one monolithic :func:`prefill` call — the
    chunk attends to everything already in the cache plus itself, under the
    same absolute-position causal/window masks — which is what lets the
    scheduler interleave prompt chunks with decode steps of other slots
    (token-equivalence locked down in tests/test_paged_prefill.py).
    This is the *gathered oracle's* chunk step (standalone batch-1 cache);
    the ``pallas_paged`` backend runs chunks through :func:`mixed_step`
    instead.  Recurrent blocks (ssm / rglru) resume their scan from the
    cached recurrent state.  ``kv_quant`` (``kv_codec="cluster"`` on the
    gathered backend) round-trips the chunk's K/V through the codec so
    later chunks attend to the same quantised keys the kernel backend
    sees, and install's page re-encode is lossless.
    """
    x = _embed_step(cfg, params, tokens)
    x, new_cache, _ = _run_stack(cfg, params, cache, x, pos=pos,
                                 kv_quant=kv_quant)
    x = rms_norm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return _unembed(cfg, params, x), new_cache


def verify_step(cfg, params, cache, tokens, pos, q_lens, *, kv_quant=False):
    """Speculative verification: score ``tokens`` (B, S) at absolute
    positions pos..pos+S-1 against a partially filled cache -> (*full*
    logits (B, S, V), new cache).

    Identical to :func:`prefill_chunk` except every position's logits are
    returned (the scheduler needs row ``i`` to check draft token ``i+1``)
    and ``q_lens`` makes the block ragged: lane ``b`` contributes
    ``q_lens[b]`` real tokens, rows past that are padding whose cache
    writes are dropped and whose logits are garbage.  A ``q_lens[b] == 0``
    lane is an exact no-op on its cache.
    """
    x = _embed_step(cfg, params, tokens)
    x, new_cache, _ = _run_stack(cfg, params, cache, x, pos=pos,
                                 q_lens=q_lens, kv_quant=kv_quant)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return _unembed(cfg, params, x), new_cache


def decode_step(cfg, params, cache, tokens, pos, *, kv_quant=False):
    """One token with a filled cache -> (logits (B,1,V), new cache).

    ``pos`` is the absolute position of ``tokens`` (vision prefix included
    for VLM archs).  ``kv_quant`` round-trips the new row's K/V through
    the cluster codec before write *and* attention (gathered backend
    under ``kv_codec="cluster"``) — quantise-then-attend, the same
    numerics the paged kernel's in-VMEM decode applies.
    """
    x = _embed_step(cfg, params, tokens)
    x, new_cache, _ = _run_stack(cfg, params, cache, x, pos=pos,
                                 kv_quant=kv_quant)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return _unembed(cfg, params, x), new_cache


def mixed_step(cfg, params, cache, table, tokens, poss, q_lens, *,
               paged_flags: tuple, page_size: int,
               q_block: int = 0, pages_per_step: int = 1,
               interpret: bool = False, scales=None):
    """One mixed serving step for *every* slot straight over the paged KV
    pools: slot ``s`` contributes ``q_lens[s]`` consecutive tokens — a
    prefill chunk, a single decode token, or nothing (``0``, a free lane)
    — out of the padded block ``tokens`` ``(S, Q)``, starting at absolute
    position ``poss[s]``.

    This is the ``pallas_paged`` backend's only step function (decode is
    the ``Q == 1``, all-``q_lens``-1 special case; the former
    ``decode_step_paged`` and the paged half of chunked prefill merged
    here): ``cache`` has the same tree structure as
    :func:`init_cache_specs` but each pageable leaf is the *physical page
    pool* shared by all slots (``(n_pages, page, ...)``; scan-stacked
    leaves keep their leading repeats axis) and each non-pageable leaf is
    a batched per-slot lane (``(n_slots, ...)``).  ``table`` ``(S, P)``
    maps logical to physical pages per slot.

    ``paged_flags`` is the flat per-leaf pageability mask from
    ``models.api.cache_layout`` (static — it picks the kernel vs lane path
    per block at trace time).  Pageable leaves take the in-kernel path:
    the chunk's K/V is scattered into the slot's pages *before* the
    kernel walks the page table (per-token causal masks preserve
    write-after-attend semantics; ragged padding is routed to the page-0
    dummy sink).  Lane leaves (rolling-window KV) run the gathered
    reference chunk attention on their lanes in the same trace, with
    write-after-attend and ragged writes dropped past ``q_lens``.  There
    is no per-step gather/scatter of the cache anywhere — for decode
    tokens *or* prefill chunks.

    Returns ``(logits (S, Q, V), new cache tree)`` with the pool leaves
    updated in place (donation-friendly: every output leaf has its input
    leaf's shape and dtype).  Logits of padded rows (``i >= q_lens[s]``)
    are garbage the caller ignores; a slot's next token comes from row
    ``q_lens[s] - 1``.

    ``scales`` (``kv_codec="cluster"``): the per-block scale-pool tree —
    pageable leaves hold int8 codebook codes, decoded in-kernel — and
    the return grows to ``(logits, new_cache, new_scales)``.
    """
    specs = init_cache_specs(cfg, 1, page_size)
    flags = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(specs), list(paged_flags))
    ctx = attn.PagedContext(table=table, page_size=page_size,
                            interpret=interpret, q_block=q_block,
                            pages_per_step=pages_per_step)
    x = _embed_step(cfg, params, tokens)
    x, new_cache, new_scales = _run_stack(cfg, params, cache, x, pos=poss,
                                          flags=flags, ctx=ctx,
                                          q_lens=q_lens, scales=scales)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if scales is not None:
        return _unembed(cfg, params, x), new_cache, new_scales
    return _unembed(cfg, params, x), new_cache
