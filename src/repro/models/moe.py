"""Mixture-of-Experts substrate (Mixtral top-2, DeepSeek-V2 shared+routed).

Sort-based capacity-bounded dispatch (GShard/Switch style): tokens are sorted
by expert id, packed into an (E, C, D) buffer (C = capacity), processed with
one grouped einsum per projection, and combined back weighted by the router
probability.  Compute is proportional to *active* parameters (top_k / E of
the expert pool), which keeps HLO_FLOPs ~ 6·N_active·D — the roofline
"useful compute" check in EXPERIMENTS.md depends on this.

Expert sharding (DESIGN.md §5): the leading E axis of the expert weights is
sharded over "model" when E divides the axis (DeepSeek: 160/16 = 10 experts
per device, true EP); otherwise the d_ff axis is TP-sharded (Mixtral: 8
experts < 16 shards).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import dense_init


def moe_init(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": dense_init(k1, d, fs, dtype),
            "up": dense_init(k2, d, fs, dtype),
            "down": dense_init(k3, fs, d, dtype),
        }
    return p


def _capacity(tokens: int, cfg) -> int:
    c = -(-int(tokens * cfg.top_k * cfg.capacity_factor)
          // cfg.num_experts)
    # floor at top_k (a group must fit one token's own experts), round to 4
    return max(cfg.top_k, -(-c // 4) * 4)


def moe_apply(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux load-balance loss scalar).

    Hierarchical (per-sequence) dispatch — EXPERIMENTS.md §Perf deepseek
    iteration D1.  The previous global argsort-based dispatch is
    unsharddable (data-dependent global permutation): GSPMD replicated the
    (T*k, D) gather/scatter buffers on every device and all-reduced the
    full (E, C, D) expert output per layer (measured 28.7 TB collective
    bytes/step on deepseek-v2 train_4k).  Here every data-dependent index
    stays *within one sequence* (cumsum-of-one-hot positions, vmapped
    row-local scatter/gather) and the combine scatter uses static indices,
    so the batch axis stays DP-sharded end-to-end and the only model-axis
    traffic is the (B, E, C, D) buffer resharding to expert-parallel
    layout — the canonical MoE all-to-all, activation-sized.
    """
    b0, s0, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    # decode (s=1): per-row dispatch would pay the top_k capacity floor per
    # token (measured 22x useful-FLOPs loss on mixtral decode_32k, §Perf
    # iter D3) — regroup tokens across the batch so capacity is shared
    if s0 == 1 and b0 > 1:
        from repro.dist.sharding import _dp_size, current_mesh
        mesh = current_mesh()
        dp = _dp_size(mesh) if mesh is not None else 16
        g = next((c for c in (dp, 16, 8, 4, 2) if b0 % c == 0), 1)
        b, s = g, b0 // g
        x = x.reshape(b, s, d)
    else:
        b, s = b0, s0
    cap = _capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                      # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (fraction routed to e) * (mean prob of e)
    onehot = (eid[..., None] == jnp.arange(e)).astype(jnp.int32)
    frac = onehot.any(2).astype(jnp.float32).mean((0, 1))
    aux = e * jnp.sum(frac * probs.mean((0, 1)))

    # ---- per-sequence positions: cumsum of one-hot along (S*k) -----------
    oh = onehot.reshape(b, s * k, e)
    cum = jnp.cumsum(oh, axis=1)                             # (B, S*k, E)
    flat_eid = eid.reshape(b, s * k)
    pos = jnp.take_along_axis(cum, flat_eid[..., None], -1)[..., 0] - 1
    keep = pos < cap
    dest = jnp.where(keep, flat_eid * cap + pos, e * cap)    # (B, S*k)
    src = jnp.arange(s * k) // k                             # static!

    # ---- row-local scatter into the expert buffer -------------------------
    def scat(xr, destr):
        return jnp.zeros((e * cap + 1, d), x.dtype).at[destr].set(
            xr[src], mode="drop", unique_indices=True)

    buf = jax.vmap(scat)(x, dest)                            # (B, E*cap+1, D)
    hidden = buf[:, :-1].reshape(b, e, cap, d)
    # expert-parallel layout: E over "model" (no-op when E % model != 0,
    # e.g. mixtral's 8 experts -> per-expert d_ff TP via the weight specs)
    hidden = constrain(hidden, "batch", "model", None, None)

    # ---- expert compute (grouped einsums, batched over B) ----------------
    act = jax.nn.silu(jnp.einsum("becd,edf->becf", hidden, p["w_gate"]))
    up = jnp.einsum("becd,edf->becf", hidden, p["w_up"])
    out_e = jnp.einsum("becf,efd->becd", act * up, p["w_down"])
    out_e = constrain(out_e, "batch", "model", None, None)
    out_rows = out_e.reshape(b, e * cap, d)

    # ---- row-local gather + static-index combine --------------------------
    def gath(bufr, destr):
        return bufr[jnp.minimum(destr, e * cap - 1)]

    slot_out = jax.vmap(gath)(out_rows, dest)                # (B, S*k, D)
    w = (gate.reshape(b, s * k) * keep).astype(x.dtype)
    weighted = slot_out * w[..., None]
    combined = weighted.reshape(b, s, k, d).sum(2)           # static combine

    if "shared" in p:
        sp = p["shared"]
        shared = (jax.nn.silu(x @ sp["gate"]) * (x @ sp["up"])) @ sp["down"]
        combined = combined + shared
    return combined.reshape(b0, s0, d), aux
