"""Family-dispatched model API: one entry point per step kind.

``get_model(cfg)`` returns a small namespace with uniform signatures so the
launcher / dry-run never branches on families.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init_params: Callable[..., Any]
    loss_fn: Callable[..., Any]              # (cfg, params, batch) -> loss
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]          # (cfg, params, cache, tok, pos)
    init_cache_specs: Callable[..., Any]
    init_cache: Callable[..., Any]


def get_model(cfg) -> ModelAPI:
    if cfg.family == "audio":
        return ModelAPI(
            init_params=encdec.init_params,
            loss_fn=encdec.loss_fn,
            forward=encdec.forward,
            prefill=encdec.prefill,
            decode_step=encdec.decode_step,
            init_cache_specs=encdec.init_cache_specs,
            init_cache=encdec.init_cache,
        )
    return ModelAPI(
        init_params=transformer.init_params,
        loss_fn=transformer.loss_fn,
        forward=transformer.forward,
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        init_cache_specs=transformer.init_cache_specs,
        init_cache=transformer.init_cache,
    )
