"""Family-dispatched model API: one entry point per step kind.

``get_model(cfg)`` returns a small namespace with uniform signatures so the
launcher / dry-run never branches on families.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init_params: Callable[..., Any]
    loss_fn: Callable[..., Any]              # (cfg, params, batch) -> loss
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]          # (cfg, params, cache, tok, pos)
    init_cache_specs: Callable[..., Any]
    init_cache: Callable[..., Any]
    prefill_chunk: Callable[..., Any] | None = None
    # (cfg, params, cache, tokens (B, S), pos) -> (last logits, new cache);
    # the gathered oracle's chunk step (standalone batch-1 cache); None
    # when the family cannot resume a prompt mid-cache (encoder-decoder)
    mixed_step: Callable[..., Any] | None = None
    # (cfg, params, paged cache, table, tokens (S, Q), poss (S,),
    #  q_lens (S,), *, paged_flags, page_size, q_block, pages_per_step,
    #  interpret)
    #   -> (logits (S, Q, V), new cache);
    # the in-kernel half of the attention-backend seam: one ragged batched
    # trace where every slot contributes q_lens[s] tokens — a prefill
    # chunk, one decode token, or nothing — against the shared page pools
    # (decode is the Q == 1 special case).  None when the family cannot
    # consume a paged cache (encoder-decoder)
    verify_step: Callable[..., Any] | None = None
    # (cfg, params, cache, tokens (B, S), pos, q_lens, *, kv_quant)
    #   -> (full logits (B, S, V), new cache);
    # speculative verification on a lane cache: prefill_chunk's ragged
    # sibling that keeps every position's logits so the scheduler can
    # accept/reject draft tokens.  None when the family cannot resume a
    # prompt mid-cache (encoder-decoder)


# TPU register tiles for f32 operands: the memory system moves (sublane,
# lane) = (8, 128) blocks, so page pools padded toward these shapes DMA
# at full bandwidth.  SlotPool pads the page (sublane) dim of every
# pageable leaf to TILE_SUBLANE and its trailing feature (lane) dim to
# TILE_LANE when hardware tiling is on; the kernel masks the page rows
# and the zero feature columns fall out of the dot products exactly.
TILE_SUBLANE = 8
TILE_LANE = 128


def round_up(n: int, tile: int) -> int:
    return -(-n // tile) * tile


def padded_page_dims(shape, len_axis: int, page_size: int,
                     hw_tiles: bool) -> tuple[int, tuple[int, ...]]:
    """Physical page layout for one pageable cache leaf.

    ``shape`` is the leaf's spec shape and ``len_axis`` its
    length-scaling axis (from :func:`cache_layout`).  Returns
    ``(page_rows, feature_dims)``: the physical rows per page and the
    (possibly lane-padded) dims trailing the page axis.  With
    ``hw_tiles=False`` this is the identity layout — ``page_size``
    logical rows, model-native features."""
    feat = tuple(shape[len_axis + 1:])
    if not hw_tiles:
        return page_size, feat
    if feat:
        feat = (*feat[:-1], round_up(feat[-1], TILE_LANE))
    return round_up(page_size, TILE_SUBLANE), feat


# the attention backends the serving stack can decode with: "gathered"
# copies each slot's pages into a contiguous lane view per step (the
# reference oracle), "pallas_paged" hands the page pool + page tables to
# mixed_step, whose Pallas kernel walks the table in-kernel
ATTN_BACKENDS = ("gathered", "pallas_paged")

# block kinds whose caches can resume a prompt mid-prefill: attention-style
# KV caches resume by construction, and the recurrent kinds (ssm / rglru)
# resume by seeding their scan from the cached recurrent state; only
# cross-attention decoders (encoder-decoder) fall back to monolithic prefill
CHUNKABLE_KINDS = frozenset(
    ("attn", "swa", "local", "global", "attn_local",
     "mla_dense", "mla_moe", "swa_moe", "moe", "ssm", "rglru"))

# block kinds the paged decode-attention backend can serve: attention-style
# caches (full-length leaves page; rolling-window leaves stay lanes and run
# the reference path in the same step); recurrent state and cross-attention
# decoders have no paged equivalent and fall back to "gathered"
PAGEABLE_KINDS = frozenset(
    ("attn", "swa", "local", "global", "attn_local",
     "mla_dense", "mla_moe", "swa_moe", "moe"))


def supports_chunked_prefill(cfg) -> bool:
    """True if ``cfg`` can run :func:`transformer.prefill_chunk`: every
    block kind keeps an attention-style cache and there is no multimodal
    prefix spliced into the prompt (vlm / audio)."""
    if cfg.family in ("vlm", "audio"):
        return False
    kinds = (tuple(cfg.prefix_kinds) + tuple(cfg.scan_pattern)
             + tuple(cfg.suffix_kinds))
    return all(k in CHUNKABLE_KINDS for k in kinds)


def supports_speculation(cfg) -> bool:
    """True if ``cfg`` can decode speculatively: draft tokens are verified
    by the same resume-from-cache machinery chunked prefill uses (the
    ragged :func:`transformer.verify_step` / ``mixed_step`` paths), so the
    gate is identical — every block resumes mid-cache and there is no
    multimodal prefix."""
    return supports_chunked_prefill(cfg)


def supports_paged_attention(cfg) -> bool:
    """True if ``cfg`` can serve with the ``pallas_paged`` attention
    backend: every block keeps an attention-style cache (pageable or
    lane-backed) and the family exposes :func:`transformer.mixed_step`."""
    if cfg.family == "audio":
        return False
    kinds = (tuple(cfg.prefix_kinds) + tuple(cfg.scan_pattern)
             + tuple(cfg.suffix_kinds))
    return all(k in PAGEABLE_KINDS for k in kinds)


def supports_prefix_share(cfg) -> bool:
    """True if ``cfg`` can map shared prefix KV pages into a new
    request's page table: chunked prefill must be resumable (the suffix
    is computed chunk by chunk from the cached span), no multimodal
    prefix may shift absolute positions, and **every** cache leaf must
    page — a rolling-window or recurrent lane would leave prefix state a
    shared page cannot carry.  Rolling-window kinds (swa / local) are
    chunkable and pageable but keep lane-backed leaves, so they are
    excluded here."""
    if not supports_chunked_prefill(cfg) or \
            not supports_paged_attention(cfg):
        return False
    kinds = (tuple(cfg.prefix_kinds) + tuple(cfg.scan_pattern)
             + tuple(cfg.suffix_kinds))
    # probing cache_layout needs an api instance; kind names are the
    # cheaper single source of truth for "has a non-length-scaling leaf"
    windowed = ("swa", "local", "attn_local", "swa_moe")
    return all(k in PAGEABLE_KINDS and k not in windowed for k in kinds)


def cache_layout(api: "ModelAPI", cfg, slot_len: int):
    """Probe the cache-spec factory for each leaf's memory role.

    Returns ``(batch_axes, len_axes)``, two tuples aligned with the flat
    leaves of ``api.init_cache_specs(cfg, 1, slot_len)``:

      * ``batch_axes[i]`` — the axis that scales with the batch argument
        (where the scheduler threads the slot dimension);
      * ``len_axes[i]``  — the axis that scales with cache length, or
        ``None`` for leaves that do not (rolling-window KV, recurrent
        state, cross-attention): these are *not pageable* and stay
        per-slot lanes under every backend.

    This probe is the single source of truth for "which leaves are
    pageable, kernel-consumable": the SlotPool uses it to build the page
    pools and ``mixed_step`` receives the pageability mask derived
    from it, so the two can never disagree about the layout.
    """
    leaves_a = jax.tree_util.tree_leaves(
        api.init_cache_specs(cfg, 1, slot_len))
    leaves_l = jax.tree_util.tree_leaves(
        api.init_cache_specs(cfg, 1, 2 * slot_len))
    leaves_b = jax.tree_util.tree_leaves(
        api.init_cache_specs(cfg, 2, slot_len))
    batch_axes, len_axes = [], []
    for sa, sl, sb in zip(leaves_a, leaves_l, leaves_b):
        bdiff = [i for i, (a, b) in enumerate(zip(sa.shape, sb.shape))
                 if a != b]
        assert bdiff == [bdiff[0]] and sa.shape[bdiff[0]] == 1 and \
            sb.shape[bdiff[0]] == 2, (sa.shape, sb.shape)
        batch_axes.append(bdiff[0])
        if sa.shape == sl.shape:
            len_axes.append(None)
            continue
        ldiff = [i for i, (a, b) in enumerate(zip(sa.shape, sl.shape))
                 if a != b]
        assert len(sa.shape) == len(sl.shape) and ldiff == [ldiff[0]] and \
            sa.shape[ldiff[0]] == slot_len and \
            sl.shape[ldiff[0]] == 2 * slot_len, (sa.shape, sl.shape)
        len_axes.append(ldiff[0])
    return tuple(batch_axes), tuple(len_axes)


def get_model(cfg) -> ModelAPI:
    if cfg.family == "audio":
        return ModelAPI(
            init_params=encdec.init_params,
            loss_fn=encdec.loss_fn,
            forward=encdec.forward,
            prefill=encdec.prefill,
            decode_step=encdec.decode_step,
            init_cache_specs=encdec.init_cache_specs,
            init_cache=encdec.init_cache,
            prefill_chunk=None,
            mixed_step=None,
            verify_step=None,
        )
    return ModelAPI(
        init_params=transformer.init_params,
        loss_fn=transformer.loss_fn,
        forward=transformer.forward,
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        init_cache_specs=transformer.init_cache_specs,
        init_cache=transformer.init_cache,
        prefill_chunk=transformer.prefill_chunk,
        mixed_step=transformer.mixed_step,
        verify_step=transformer.verify_step,
    )
