"""Family-dispatched model API: one entry point per step kind.

``get_model(cfg)`` returns a small namespace with uniform signatures so the
launcher / dry-run never branches on families.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init_params: Callable[..., Any]
    loss_fn: Callable[..., Any]              # (cfg, params, batch) -> loss
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]          # (cfg, params, cache, tok, pos)
    init_cache_specs: Callable[..., Any]
    init_cache: Callable[..., Any]
    prefill_chunk: Callable[..., Any] | None = None
    # (cfg, params, cache, tokens (B, S), pos) -> (last logits, new cache);
    # None when the family cannot resume a prompt mid-cache (encoder-decoder)


# block kinds whose caches can resume a prompt mid-prefill (attention-style
# KV caches); recurrent states (ssm / rglru) and cross-attention decoders
# cannot, so configs containing them fall back to monolithic prefill
CHUNKABLE_KINDS = frozenset(
    ("attn", "swa", "local", "global", "attn_local",
     "mla_dense", "mla_moe", "swa_moe", "moe"))


def supports_chunked_prefill(cfg) -> bool:
    """True if ``cfg`` can run :func:`transformer.prefill_chunk`: every
    block kind keeps an attention-style cache and there is no multimodal
    prefix spliced into the prompt (vlm / audio)."""
    if cfg.family in ("vlm", "audio"):
        return False
    kinds = (tuple(cfg.prefix_kinds) + tuple(cfg.scan_pattern)
             + tuple(cfg.suffix_kinds))
    return all(k in CHUNKABLE_KINDS for k in kinds)


def get_model(cfg) -> ModelAPI:
    if cfg.family == "audio":
        return ModelAPI(
            init_params=encdec.init_params,
            loss_fn=encdec.loss_fn,
            forward=encdec.forward,
            prefill=encdec.prefill,
            decode_step=encdec.decode_step,
            init_cache_specs=encdec.init_cache_specs,
            init_cache=encdec.init_cache,
            prefill_chunk=None,
        )
    return ModelAPI(
        init_params=transformer.init_params,
        loss_fn=transformer.loss_fn,
        forward=transformer.forward,
        prefill=transformer.prefill,
        decode_step=transformer.decode_step,
        init_cache_specs=transformer.init_cache_specs,
        init_cache=transformer.init_cache,
        prefill_chunk=transformer.prefill_chunk,
    )
