"""Attention substrate: flash-style chunked attention, GQA, sliding windows,
prefix-LM masks, logit softcaps, KV caches (full + rolling-window), and
DeepSeek-style MLA with latent-space decode.

Memory discipline: training/prefill attention never materialises an (Sq, Sk)
score matrix — it runs an online-softmax scan over (q_chunk, kv_chunk) tiles,
so activation memory is linear in sequence length (required for the 32k
prefill cells and scan-over-layers remat).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain, seq_shard_attention
from repro.models.layers import apply_rope, dense_init, rms_norm, softcap

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class PagedContext:
    """Per-step state of the ``pallas_paged`` attention backend.

    Present (non-None) only on blocks whose cache leaves are page pools:
    ``table`` maps each slot's logical pages to physical pages of the
    shared pool, ``page_size`` is the positions-per-page layout constant,
    and ``interpret`` routes the Pallas kernel through the interpreter on
    hosts without a TPU.  Blocks whose leaves stay per-slot lanes
    (rolling-window KV, recurrent state) receive ``paged=None`` and run
    the gathered reference path on their lanes.

    ``page_size`` is the *logical* positions-per-page constant; the pool
    leaves themselves may carry hardware-tiled padding (page rows padded
    to the sublane tile, trailing feature dim to the lane tile — see
    ``api.padded_page_dims``), which :meth:`write` fills with zeros and
    the kernel masks out.  ``q_block`` / ``pages_per_step`` are the
    tuned kernel launch parameters (``runtime.autotune.tune_kernel``).
    """

    table: jax.Array         # (S, pages_per_slot) int32
    page_size: int
    interpret: bool = False
    q_block: int = 0         # kernel query-block width (0 = whole Q)
    pages_per_step: int = 1  # physical pages per kernel grid step

    def write(self, pool: jax.Array, values: jax.Array, pos,
              q_lens=None) -> jax.Array:
        """Scatter this step's per-slot token block ``values`` (S, Q, ...)
        into each slot's pages of ``pool`` (n_pages, page, ...): token
        ``i`` of slot ``s`` lands at absolute position ``pos[s] + i`` for
        ``i < q_lens[s]``; padded tokens of the ragged mixed-step block
        (``i >= q_lens[s]``, or everything when ``q_lens[s] == 0``) are
        routed to the page-0 dummy sink instead.  ``q_lens=None`` means
        every token is real.  This is the layout contract the paged
        kernel depends on: the chunk's K/V is in the pool *before* the
        kernel walks the table (per-token causal masks keep
        write-after-attend semantics)."""
        s_n, qn = values.shape[:2]
        if values.shape[2:] != pool.shape[2:]:
            # hardware-tiled pool: zero-fill the lane padding so padded
            # feature columns decode/score to exactly 0
            values = jnp.pad(values, [(0, 0), (0, 0)] + [
                (0, dp - dv) for dp, dv in
                zip(pool.shape[2:], values.shape[2:])])
        p = jnp.asarray(pos, jnp.int32)[:, None] \
            + jnp.arange(qn, dtype=jnp.int32)[None]           # (S, Q)
        lidx = jnp.clip(p // self.page_size, 0, self.table.shape[1] - 1)
        pids = jnp.take_along_axis(self.table, lidx, axis=1)
        if q_lens is not None:
            valid = jnp.arange(qn)[None] < \
                jnp.asarray(q_lens, jnp.int32)[:, None]
            pids = jnp.where(valid, pids, 0)
            p = jnp.where(valid, p, 0)
        return pool.at[pids, p % self.page_size].set(
            values.astype(pool.dtype))


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def _allowed(q_pos, k_pos, *, causal: bool, window: int, prefix_len: int):
    """Boolean mask (..., Sq, Sk) of attendable pairs."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    ok = (k <= q) if causal else jnp.ones(jnp.broadcast_shapes(
        q.shape, k.shape), bool)
    if window:
        ok &= k > q - window
    if prefix_len:
        ok |= k < prefix_len
    return ok


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "prefix_len", "attn_softcap",
                     "q_chunk", "kv_chunk"))
def flash_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, KH, D)
    v: jax.Array,            # (B, Sk, KH, Dv)
    *,
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    attn_softcap: float = 0.0,
    q_offset: int = 0,
    q_chunk: int = 4096,
    kv_chunk: int = 0,       # kept for API compat; kv is processed densely
) -> jax.Array:
    """Memory-chunked attention: lax.scan over q chunks, dense over kv.

    Design note (EXPERIMENTS.md §Perf iter 2): an inner kv-chunk scan makes
    the backward emit a dK/dV all-reduce *per kv chunk per q chunk* when q
    is sequence-sharded and k/v replicated (measured 112 GB/step on gemma2
    train_4k).  With kv dense inside the q-scan, dK/dV accumulate in the
    scan carry locally and are reduced once per layer (~1.7 GB/step).  The
    (cq, Sk) score block is transient and recomputed under remat.
    """
    b, sq, h, d = q.shape
    sk, kh, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kh
    q_chunk = math.gcd(sq, q_chunk)        # largest common divisor <= chunk

    nq = sq // q_chunk
    # scores/PV run on the MXU in the model dtype with f32 accumulation;
    # only the softmax statistics stay f32 (halves attention bytes & flops
    # vs an all-f32 flash — §Perf iter 5)
    qs = (q * jnp.asarray(d ** -0.5, q.dtype)).reshape(
        b, nq, q_chunk, kh, g, d)
    qs = jnp.moveaxis(qs, 1, 0)                       # (nq, B, cq, KH, G, D)
    # Attention sharding over the "model" axis (DESIGN.md §4): shard KV
    # heads when they divide the axis (MLA's 128 heads), else shard the q
    # rows (GQA archs with 1-10 kv heads).  Without an explicit constraint
    # GSPMD replicates the whole score block on every model rank (measured:
    # 16x redundant attention FLOPs on the 16x16 mesh).
    from repro.dist.sharding import current_mesh
    mesh = current_mesh()
    head_tp = mesh is not None and "model" in mesh.axis_names and \
        kh % mesh.shape.get("model", 1) == 0
    if head_tp:
        qs = constrain(qs, None, "batch", None, "model", None, None)
    else:
        qs = constrain(qs, None, "batch", "model", None, None, None)
    k_pos = jnp.arange(sk)

    def q_step(_, qx):
        qc, qi = qx
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        if head_tp:
            qc = constrain(qc, "batch", None, "model", None, None)
        else:
            qc = constrain(qc, "batch", "model", None, None, None)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, k,
                       preferred_element_type=jnp.float32)
        if attn_softcap:
            s = softcap(s, attn_softcap)
        mask = _allowed(q_pos, k_pos, causal=causal, window=window,
                        prefix_len=prefix_len)            # (cq, Sk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        if head_tp:
            s = constrain(s, "batch", "model", None, None, None)
        else:
            s = constrain(s, "batch", None, None, "model", None)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(-1, keepdims=True)
        out = jnp.einsum("bhgqk,bkhd->bhgqd",
                         (p / jnp.maximum(l, 1e-20)).astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        if head_tp:
            out = constrain(out, "batch", "model", None, None, None)
        else:
            out = constrain(out, "batch", None, None, "model", None)
        return None, out                                  # (B, KH, G, cq, Dv)

    if nq == 1:
        # dense path: one score block per layer -> dK/dV reduce ONCE per
        # layer instead of once per scan step (the scan form psums the
        # replicated-K cotangent on every iteration; measured 223 GB/step)
        _, out1 = q_step(None, (qs[0], jnp.zeros((), jnp.int32)))
        outs = out1[None]
    else:
        # remat each q chunk: the (cq, Sk) score block would otherwise be
        # saved per scan step for the backward (nq x 0.5 GB of residuals)
        _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                               (qs, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1)                        # (B, nq, KH, G, cq, Dv)
    out = jnp.moveaxis(out, -2, 2)                        # (B, nq, cq, KH, G, Dv)
    return out.reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# decode attention over a cache
# ---------------------------------------------------------------------------

def decode_attention(
    q: jax.Array,            # (B, 1, H, D)
    k_cache: jax.Array,      # (B, Smax, KH, D)
    v_cache: jax.Array,      # (B, Smax, KH, Dv)
    cur_pos: jax.Array,      # () shared or (B,) per-lane current position
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    rolling: bool = False,
) -> jax.Array:
    """Reference decode attention over a contiguous per-lane cache.

    ``cur_pos`` may be a scalar (every lane at the same depth — the wave
    path) or a ``(B,)`` vector (slot serving: each lane has its own
    position).  This is the oracle the ``pallas_paged`` kernel backend is
    tested against.
    """
    b, smax, kh, d = k_cache.shape
    h = q.shape[2]
    g = h // kh
    qs = (q.astype(jnp.float32) * d ** -0.5).reshape(b, kh, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qs, k_cache.astype(jnp.float32))
    if attn_softcap:
        s = softcap(s, attn_softcap)
    slot = jnp.arange(smax)
    cur = jnp.asarray(cur_pos)[..., None]        # (1,) or (B, 1)
    if rolling:
        # rolling window cache: slots hold the last min(cur_pos+1, Smax) keys
        valid = slot < jnp.minimum(cur + 1, smax)
    else:
        valid = slot <= cur
        if window:
            valid &= slot > cur - window
    valid = valid if valid.ndim == 2 else valid[None]      # (B|1, Smax)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, v_cache.shape[-1])


# ---------------------------------------------------------------------------
# chunked-prefill attention over a partially filled cache
# ---------------------------------------------------------------------------

def chunk_attention(
    q: jax.Array,            # (B, S, H, D)   chunk queries
    k: jax.Array,            # (B, S, KH, D)  chunk keys
    v: jax.Array,            # (B, S, KH, Dv) chunk values
    k_past: jax.Array,       # (B, P, KH, D)  resident cache (physical order)
    v_past: jax.Array,       # (B, P, KH, Dv)
    q_pos: jax.Array,        # (S,) | (B, S) absolute chunk-token positions
    k_pos: jax.Array,        # (P,) | (B, P) absolute past-key pos (<0: hole)
    *,
    window: int = 0,
    attn_softcap: float = 0.0,
    q_lens: jax.Array | None = None,   # (B,) real tokens per lane (ragged)
) -> jax.Array:
    """Attention of a prefill chunk against (resident cache ++ chunk) keys.

    The cache may be physically reordered (rolling-window slots) or contain
    never-written holes; ``k_pos`` carries each physical slot's absolute
    position (negative = not a real key), so causality and windowing are
    enforced on absolute positions, exactly as monolithic prefill's mask
    would.  The chunk's own keys are appended *after* the resident ones so
    rolling caches whose chunk writes would overwrite still-needed old keys
    stay attendable (write-back happens after this call).

    ``q_pos``/``k_pos`` may carry a leading lane axis (mixed-step serving:
    every lane at its own depth) and ``q_lens`` marks the ragged padding —
    tokens at ``i >= q_lens[b]`` neither act as keys nor produce
    meaningful output (the caller discards their rows).
    """
    kk = jnp.concatenate([k_past.astype(jnp.float32),
                          k.astype(jnp.float32)], axis=1)
    vv = jnp.concatenate([v_past.astype(jnp.float32),
                          v.astype(jnp.float32)], axis=1)
    b, s, h, d = q.shape
    q_pos2 = jnp.asarray(q_pos)
    q_pos2 = q_pos2[None] if q_pos2.ndim == 1 else q_pos2      # (B|1, S)
    k_pos2 = jnp.asarray(k_pos)
    k_pos2 = k_pos2[None] if k_pos2.ndim == 1 else k_pos2      # (B|1, P)
    chunk_pos = q_pos2
    if q_lens is not None:
        chunk_pos = jnp.where(
            jnp.arange(s)[None] < jnp.asarray(q_lens)[:, None], q_pos2, -1)
    bb = max(q_pos2.shape[0], k_pos2.shape[0], chunk_pos.shape[0])
    pos_all = jnp.concatenate(
        [jnp.broadcast_to(k_pos2, (bb, k_pos2.shape[1])),
         jnp.broadcast_to(chunk_pos, (bb, s))], axis=1)        # (B|1, P+S)
    kh = kk.shape[2]
    g = h // kh
    qs = (q.astype(jnp.float32) * d ** -0.5).reshape(b, s, kh, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qs, kk)
    if attn_softcap:
        sc = softcap(sc, attn_softcap)
    ok = (pos_all[:, None, :] <= q_pos2[..., None]) & \
        (pos_all[:, None, :] >= 0)
    if window:
        ok &= pos_all[:, None, :] > q_pos2[..., None] - window
    sc = jnp.where(ok[:, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vv)
    return out.reshape(b, s, h, vv.shape[-1])


def _codec_roundtrip(x: jax.Array, axes: tuple) -> jax.Array:
    """Quantise ``x`` onto the ``kv_codec="cluster"`` codebook and decode
    it straight back (one scale per the block trailing ``axes``).

    The gathered backend's chunked prefill uses this to reproduce the
    ``pallas_paged`` mixed step's numerics exactly: the kernel path
    encodes each chunk's K/V into the code pools and attends to the
    *decoded* codes, so later chunks see quantised keys.  Round-tripping
    here makes the standalone-chunk oracle see the same values — and
    because the codec encode is idempotent (``encode(decode(encode(x)))
    == encode(x)``), the install-time re-encode then lands bit-identical
    codes in the pool."""
    from repro.kernels import kv_codec
    codes, sc = kv_codec.encode(x, axes)
    rest = codes.ndim - sc.ndim
    return kv_codec.decode(
        codes, sc.reshape(*sc.shape, *(1,) * rest)).astype(x.dtype)


def _rolling_slot_positions(pos, smax: int) -> jax.Array:
    """Absolute position held by each physical slot of a rolling cache
    *before* positions >= ``pos`` are written (negative = never written).

    Position p lands at slot p % smax, so slot j holds the largest
    p < pos with p === j (mod smax).  ``pos`` may be a scalar (one lane /
    shared depth) or a ``(B,)`` vector (per-lane depths -> (B, smax))."""
    slot = jnp.arange(smax)
    last = jnp.asarray(pos)[..., None] - 1
    return (last - (last - slot) % smax).reshape(
        (-1, smax) if jnp.ndim(pos) else (smax,))


def _lane_chunk_write(cache: jax.Array, new: jax.Array, pos,
                      q_lens=None, *, rolling: bool) -> jax.Array:
    """Scatter chunk K/V ``new`` (B, S, ...) into per-lane caches at
    per-lane positions ``pos`` (scalar or (B,)).  Rolling caches wrap at
    slot ``p % smax`` and only the last ``smax`` real tokens survive when
    a lane's chunk exceeds the window; ``q_lens`` marks ragged padding
    (those writes are dropped, never clobbering live positions)."""
    b, s = new.shape[:2]
    smax = cache.shape[1]
    i = jnp.arange(s)[None]                                   # (1, S)
    pos = jnp.broadcast_to(
        jnp.asarray(pos, jnp.int32).reshape(-1, 1), (b, 1))
    ql = (jnp.full((b, 1), s, jnp.int32) if q_lens is None
          else jnp.asarray(q_lens, jnp.int32)[:, None])
    keep = i < ql
    if rolling:
        keep &= i >= ql - smax
        idx = jnp.where(keep, (pos + i) % smax, smax)
    else:
        idx = jnp.where(keep, pos + i, smax)
    lane = jnp.arange(b)[:, None]
    return cache.at[lane, idx].set(new.astype(cache.dtype), mode="drop")


# ---------------------------------------------------------------------------
# standard GQA attention layer (init / train / prefill+cache / decode)
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype) -> dict:
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, h * hd, dtype),
        "wk": dense_init(kk, d, kh * hd, dtype),
        "wv": dense_init(kv, d, kh * hd, dtype),
        "wo": dense_init(ko, h * hd, d, dtype),
    }


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kh, hd)
    v = (x @ p["wv"]).reshape(b, s, kh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(
    p: dict, x: jax.Array, cfg, *,
    kind: str,                       # "attn" | "swa" | "local" | "global" | "bidir"
    cache: dict | None = None,       # None = train; dict = prefill/decode
    pos=None,                        # decode: () shared or (B,) per-lane pos
    prefix_len: int = 0,
    paged: PagedContext | None = None,
    q_lens: jax.Array | None = None,  # (B,) real tokens per lane (ragged
    #                                    mixed step; None = all real)
    scales: dict | None = None,       # kv_codec="cluster": {"k","v"} scale
    #                                    pools (n_pages, page) f32; implies
    #                                    paged + int8 code pools
    kv_quant: bool = False,           # kv_codec="cluster" on a *lane* cache:
    #                                    round-trip chunk K/V through the
    #                                    codec so install re-encodes losslessly
) -> tuple[jax.Array, dict | None]:
    """-> (y, new_cache); with ``scales`` -> (y, new_cache, new_scales)."""
    b, s, _ = x.shape
    window = cfg.window if kind in ("swa", "local") else 0
    causal = kind != "bidir"
    decode = cache is not None and s == 1 and q_lens is None
    chunked = cache is not None and pos is not None and paged is None and \
        (s > 1 or q_lens is not None)

    if paged is not None:
        # ``pallas_paged`` backend: the cache leaves are the physical page
        # pools (n_pages, page, KH, HD) shared by every slot; this step's
        # token block — 1..s tokens per slot, a prefill chunk or a single
        # decode token — is scattered into each slot's pages and attention
        # walks the page table inside the kernel, with per-token causal
        # masks standing in for write-after-attend.  No contiguous
        # per-slot view is ever gathered.
        from repro.kernels.paged_attention import paged_mixed_attention
        pos = jnp.asarray(pos, jnp.int32)
        ql = (jnp.full((b,), s, jnp.int32) if q_lens is None
              else jnp.asarray(q_lens, jnp.int32))
        positions = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        q, k, v = _qkv(p, x, cfg, positions)
        hd = cfg.head_dim
        kw = {}
        if scales is not None:
            # kv_codec="cluster": quantize this step's K/V onto the
            # codebook (one scale per (slot, token)), scatter the int8
            # codes + scale rows, and let the kernel decode each page in
            # VMEM — the fp cache never exists.
            from repro.kernels import kv_codec
            k, k_sc = kv_codec.encode(k, axes=(-2, -1))
            v, v_sc = kv_codec.encode(v, axes=(-2, -1))
            new_scales = {"k": paged.write(scales["k"], k_sc, pos, q_lens),
                          "v": paged.write(scales["v"], v_sc, pos, q_lens)}
            kw = dict(k_scales=new_scales["k"], v_scales=new_scales["v"],
                      codebook=kv_codec.codebook())
        k_pool = paged.write(cache["k"], k, pos, q_lens)
        v_pool = paged.write(cache["v"], v, pos, q_lens)
        out = paged_mixed_attention(
            (q.astype(jnp.float32) * hd ** -0.5), k_pool, v_pool,
            paged.table, pos + ql, ql, window=window,
            softcap_val=cfg.attn_logit_softcap,
            page_size=paged.page_size, q_block=paged.q_block,
            pages_per_step=paged.pages_per_step,
            interpret=paged.interpret, **kw)[..., :hd]
        y = out.reshape(b, s, -1).astype(x.dtype) @ p["wo"]
        new_cache = {"k": k_pool, "v": v_pool}
        if scales is not None:
            return y, new_cache, new_scales
        return y, new_cache

    if chunked:
        # chunked prefill / mixed lane step: 1..s tokens per lane at
        # absolute positions pos..pos+len-1 against a partially filled
        # cache.  Attention runs over (resident cache ++ chunk) with
        # absolute-position masks; the chunk's K/V is written back
        # afterwards so rolling windows never read their own overwrites.
        q_pos = jnp.asarray(pos)[..., None] + jnp.arange(s)  # (S,) | (B,S)
        positions = q_pos if q_pos.ndim == 2 else q_pos[None, :]
        q, k, v = _qkv(p, x, cfg, positions)
        smax = cache["k"].shape[1]
        rolling = bool(window)
        if kv_quant and not rolling:
            # rolling-window lanes stay raw under the kernel backend too
            # (their pages never enter the code pools), so only full-history
            # lanes quantise here.
            k = _codec_roundtrip(k, (-2, -1))
            v = _codec_roundtrip(v, (-2, -1))
        if rolling:
            k_pos = _rolling_slot_positions(pos, smax)
        else:
            slot = jnp.arange(smax)
            k_pos = jnp.where(slot < jnp.asarray(pos)[..., None], slot, -1)
        out = chunk_attention(q, k, v, cache["k"], cache["v"], q_pos, k_pos,
                              window=window,
                              attn_softcap=cfg.attn_logit_softcap,
                              q_lens=q_lens)
        new_cache = {
            "k": _lane_chunk_write(cache["k"], k, pos, q_lens,
                                   rolling=rolling),
            "v": _lane_chunk_write(cache["v"], v, pos, q_lens,
                                   rolling=rolling),
        }
    elif decode:
        rolling = bool(window)
        if jnp.ndim(pos) == 0:           # shared position (wave decode)
            positions = jnp.full((b, 1), pos, jnp.int32)
            q, k, v = _qkv(p, x, cfg, positions)
            if kv_quant and not rolling:
                # quantise-then-attend, matching the kernel backend: the
                # new row's key/value enter this step's softmax already
                # on the codebook, exactly as every later step sees them
                k = _codec_roundtrip(k, (-2, -1))
                v = _codec_roundtrip(v, (-2, -1))
            slot = pos % cache["k"].shape[1] if rolling else pos
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        else:                            # (B,) per-lane positions
            positions = jnp.asarray(pos, jnp.int32)[:, None]
            q, k, v = _qkv(p, x, cfg, positions)
            if kv_quant and not rolling:
                k = _codec_roundtrip(k, (-2, -1))
                v = _codec_roundtrip(v, (-2, -1))
            slot = positions[:, 0] % cache["k"].shape[1] if rolling \
                else positions[:, 0]
            lane = jnp.arange(b)
            k_cache = cache["k"].at[lane, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[lane, slot].set(
                v[:, 0].astype(cache["v"].dtype))
        out = decode_attention(q, k_cache, v_cache, pos, window=window,
                               attn_softcap=cfg.attn_logit_softcap,
                               rolling=rolling)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        positions = jnp.arange(s)[None, :]
        q, k, v = _qkv(p, x, cfg, positions)
        q, k, v = seq_shard_attention(q, k, v)   # SP layout (dist.sharding)
        out = flash_attention(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            attn_softcap=cfg.attn_logit_softcap)
        out = constrain(out, "batch", "model", None, None)
        new_cache = None
        if cache is not None:                      # prefill: fill the cache
            smax = cache["k"].shape[1]
            if window and smax < s:                # rolling window cache:
                # position p must land at slot p % smax for decode to append
                shift = s % smax
                k_keep = jnp.roll(k[:, -smax:], shift, axis=1)
                v_keep = jnp.roll(v[:, -smax:], shift, axis=1)
            else:
                k_keep = jnp.pad(k, ((0, 0), (0, smax - min(s, smax)),
                                     (0, 0), (0, 0)))[:, :smax]
                v_keep = jnp.pad(v, ((0, 0), (0, smax - min(s, smax)),
                                     (0, 0), (0, 0)))[:, :smax]
            new_cache = {"k": k_keep.astype(cache["k"].dtype),
                         "v": v_keep.astype(cache["v"].dtype)}
    y = out.reshape(b, s, -1).astype(x.dtype) @ p["wo"]
    return y, new_cache


def attn_cache_spec(cfg, kind: str, batch: int, max_len: int):
    """ShapeDtypeStructs of this layer kind's cache."""
    window = cfg.window if kind in ("swa", "local") else 0
    length = min(window, max_len) if window else max_len
    shp = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    dt = cfg.jnp_dtype
    return {"k": jax.ShapeDtypeStruct(shp, dt),
            "v": jax.ShapeDtypeStruct(shp, dt)}


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype) -> dict:
    return attn_init(key, cfg, dtype)


def cross_attn_apply(p, x, cfg, *, enc_kv=None, enc_out=None):
    """enc_kv: precomputed {"k","v"} (prefill caches them); else compute from
    enc_out."""
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    if enc_kv is None:
        se = enc_out.shape[1]
        k = (enc_out @ p["wk"]).reshape(b, se, kh, hd)
        v = (enc_out @ p["wv"]).reshape(b, se, kh, hd)
    else:
        k, v = enc_kv["k"], enc_kv["v"]
    out = flash_attention(q, k, v, causal=False)
    return out.reshape(b, s, -1).astype(x.dtype) @ p["wo"], {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV compression, absorbed decode
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], d, r_q, dtype),
        "q_norm": jnp.zeros((r_q,), dtype),
        "w_uq": dense_init(ks[1], r_q, h * (dn + dr), dtype),
        "w_dkv": dense_init(ks[2], d, r_kv + dr, dtype),
        "kv_norm": jnp.zeros((r_kv,), dtype),
        "w_uk": dense_init(ks[3], r_kv, h * dn, dtype),
        "w_uv": dense_init(ks[4], r_kv, h * dv, dtype),
        "wo": dense_init(ks[5], h * dv, d, dtype),
    }


def mla_apply(p, x, cfg, *, cache=None, pos=None, paged=None, q_lens=None,
              scales=None, kv_quant=False):
    """-> (y, new_cache); with ``scales`` -> (y, new_cache, new_scales)."""
    b, s, d = x.shape
    h = cfg.num_heads
    r_kv = cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    # the absorbed-latent branch serves both single-token decode (s == 1)
    # and chunked prefill (s > 1): every einsum already carries the s axis,
    # only the causal mask needs per-query positions
    decode = cache is not None and pos is not None
    if paged is not None:
        positions = jnp.asarray(pos, jnp.int32)[:, None] \
            + jnp.arange(s, dtype=jnp.int32)[None]            # (B, S)
    else:
        positions = (pos + jnp.arange(s)[None, :] if decode
                     else jnp.arange(s)[None, :])

    cq = rms_norm(p["q_norm"], x @ p["w_dq"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]                                  # (B, S, r_kv + dr)
    c_kv = rms_norm(p["kv_norm"], dkv[..., :r_kv], cfg.norm_eps)
    k_pe = apply_rope(dkv[..., None, r_kv:], positions, cfg.rope_theta)[:, :, 0]

    if paged is not None:
        # absorbed attention straight over the paged latent pools — one
        # ragged mixed-step block of 1..s tokens per slot: the MLA latent
        # is one shared KV "head" whose key has a latent part (c_kv,
        # scored against q absorbed through w_uk) and a rope part (k_pe)
        # — exactly the kernel's (q, k) + (q2, k2) split, with the latent
        # pool doubling as the value pool.
        from repro.kernels.paged_attention import paged_mixed_attention
        pos = jnp.asarray(pos, jnp.int32)
        ql = (jnp.full((b,), s, jnp.int32) if q_lens is None
              else jnp.asarray(q_lens, jnp.int32))
        kw = {}
        if scales is not None:
            # kv_codec="cluster" over the latent pools: the latent (c_kv)
            # doubles as key and value so its scale pool rides both
            # operands; the rope part (k_pe) is the second-score operand.
            from repro.kernels import kv_codec
            c_kv, c_sc = kv_codec.encode(c_kv, axes=(-1,))
            k_pe, pe_sc = kv_codec.encode(k_pe, axes=(-1,))
            new_scales = {
                "c_kv": paged.write(scales["c_kv"], c_sc, pos, q_lens),
                "k_pe": paged.write(scales["k_pe"], pe_sc, pos, q_lens)}
            kw = dict(k_scales=new_scales["c_kv"],
                      v_scales=new_scales["c_kv"],
                      k2_scales=new_scales["k_pe"],
                      codebook=kv_codec.codebook())
        c_pool = paged.write(cache["c_kv"], c_kv, pos, q_lens)
        pe_pool = paged.write(cache["k_pe"], k_pe, pos, q_lens)
        w_uk = p["w_uk"].reshape(r_kv, h, dn)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))      # (B, S, H, r_kv)
        ctx = paged_mixed_attention(
            q_lat, c_pool[:, :, None], c_pool[:, :, None],
            paged.table, pos + ql, ql,
            q_pe.astype(jnp.float32), pe_pool[:, :, None],
            scale=(dn + dr) ** -0.5, page_size=paged.page_size,
            q_block=paged.q_block, pages_per_step=paged.pages_per_step,
            interpret=paged.interpret, **kw)[..., :r_kv]
        w_uv = p["w_uv"].reshape(r_kv, h, dv)
        out = jnp.einsum("bshr,rhv->bshv", ctx,
                         w_uv.astype(jnp.float32))        # (B, S, H, dv)
        y = out.reshape(b, s, h * dv).astype(x.dtype) @ p["wo"]
        new_cache = {"c_kv": c_pool, "k_pe": pe_pool}
        if scales is not None:
            return y, new_cache, new_scales
        return y, new_cache

    if decode:
        if kv_quant:
            c_kv = _codec_roundtrip(c_kv, (-1,))
            k_pe = _codec_roundtrip(k_pe, (-1,))
        if q_lens is None:
            c_cache = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
            pe_cache = jax.lax.dynamic_update_slice(
                cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, pos, 0))
        else:
            # ragged speculative verification: only rows < q_lens are real
            # — rejected-draft and padding rows are routed out of bounds
            # and dropped, so the cache never sees them (a q_lens == 0
            # lane is an exact no-op)
            ql = jnp.asarray(q_lens, jnp.int32)
            rows = pos + jnp.arange(s)[None, :]               # (1, S)
            rows = jnp.where(jnp.arange(s)[None, :] < ql[:, None],
                             rows, cache["c_kv"].shape[1])
            lane = jnp.arange(b)[:, None]
            c_cache = cache["c_kv"].at[lane, rows].set(
                c_kv.astype(cache["c_kv"].dtype), mode="drop")
            pe_cache = cache["k_pe"].at[lane, rows].set(
                k_pe.astype(cache["k_pe"].dtype), mode="drop")
        # absorbed attention in latent space
        w_uk = p["w_uk"].reshape(r_kv, h, dn)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))      # (B, 1, H, r_kv)
        scale = (dn + dr) ** -0.5
        s_lat = jnp.einsum("bshr,bkr->bhsk", q_lat,
                           c_cache.astype(jnp.float32))
        s_pe = jnp.einsum("bshd,bkd->bhsk", q_pe.astype(jnp.float32),
                          pe_cache.astype(jnp.float32))
        scores = (s_lat + s_pe) * scale                # (B, H, s, K)
        q_pos = pos + jnp.arange(s)
        valid = jnp.arange(c_cache.shape[1])[None, :] <= q_pos[:, None]
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhsk,bkr->bshr", probs,
                         c_cache.astype(jnp.float32))     # (B, 1, H, r_kv)
        w_uv = p["w_uv"].reshape(r_kv, h, dv)
        out = jnp.einsum("bshr,rhv->bshv", ctx, w_uv.astype(jnp.float32))
        new_cache = {"c_kv": c_cache, "k_pe": pe_cache}
    else:
        k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, dn)
        v = (c_kv @ p["w_uv"]).reshape(b, s, h, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None], (b, s, h, dr))], -1)
        q_full = jnp.concatenate([q_nope, q_pe], -1)
        # MLA has 128 heads: head-TP divides the 16-wide model axis cleanly
        q_full = constrain(q_full, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
        out = flash_attention(q_full, k, v, causal=True)
        out = constrain(out, "batch", None, "model", None)
        new_cache = None
        if cache is not None:
            smax = cache["c_kv"].shape[1]
            ck = jnp.pad(c_kv, ((0, 0), (0, smax - s), (0, 0)))
            pk = jnp.pad(k_pe, ((0, 0), (0, smax - s), (0, 0)))
            new_cache = {"c_kv": ck.astype(cache["c_kv"].dtype),
                         "k_pe": pk.astype(cache["k_pe"].dtype)}
    y = out.reshape(b, s, h * dv).astype(x.dtype) @ p["wo"]
    return y, new_cache


def mla_cache_spec(cfg, batch: int, max_len: int):
    dt = cfg.jnp_dtype
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
        "k_pe": jax.ShapeDtypeStruct((batch, max_len, cfg.rope_head_dim), dt),
    }
