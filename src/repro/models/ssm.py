"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear state passing between chunks); decode is the O(1) recurrent update on
a (B, H, P, N) state.  Group count G divides heads (mamba2-780m: G=1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.layers import dense_init, rms_norm


def _segsum(a: jax.Array) -> jax.Array:
    """(..., Q) -> (..., Q, Q) lower-triangular segment sums:
    out[..., i, j] = sum_{j < l <= i} a[..., l] (=-inf above diagonal)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in = cfg.expand * d
    h, n, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * g * n + h
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel,
                                             d_in + 2 * g * n)) * 0.1
                   ).astype(dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, d, dtype),
    }


def _split_proj(cfg, z_all):
    d_in = cfg.expand * cfg.d_model
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = jnp.split(z_all, [d_in, 2 * d_in + 2 * g * n], axis=-1)
    return z, xbc, dt  # gate, conv-input, dt (.., h)


def _causal_conv(xbc, conv_w, state=None, q_lens=None):
    """Depthwise causal conv over time. xbc (B, S, C); conv_w (K, C).
    state (B, K-1, C) carries context across decode steps.  With ragged
    ``q_lens`` the carried-out state is read at each lane's own valid
    length (``q_lens[b] == 0`` returns the incoming state unchanged)."""
    k = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    if q_lens is None:
        new_state = full[:, -(k - 1):]
    else:
        idx = (jnp.asarray(q_lens, jnp.int32)[:, None]
               + jnp.arange(k - 1)[None, :])
        new_state = jnp.take_along_axis(full, idx[..., None], axis=1)
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a_log, b, c, chunk: int, init=None):
    """Chunked SSD scan.

    x (B, S, H, P); dt (B, S, H) post-softplus; b, c (B, S, G, N).
    ``init`` (B, H, P, N) seeds the inter-chunk recurrence (resuming the
    scan from a cached state); None starts from zeros.
    Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    bsz, s, h, p_dim = x.shape
    g = b.shape[2]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g
    a = -jnp.exp(a_log)                                       # (H,)

    xc = x.reshape(bsz, nc, chunk, h, p_dim)
    dtc = dt.reshape(bsz, nc, chunk, h)
    n_state = b.shape[-1]
    bc = b.reshape(bsz, nc, chunk, g, n_state)
    cc = c.reshape(bsz, nc, chunk, g, n_state)
    if g != h:
        bc = jnp.repeat(bc, rep, axis=3)
        cc = jnp.repeat(cc, rep, axis=3)

    da = dtc * a                                              # (B,nc,Q,H)
    da_cs = jnp.cumsum(da, axis=2)                            # (B,nc,Q,H)
    xdt = xc * dtc[..., None]

    # intra-chunk (diagonal) term
    l_mat = jnp.exp(_segsum(jnp.moveaxis(da, 2, 3)))          # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc)
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                        scores, l_mat, xdt)

    # chunk-final states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)       # (B,nc,Q,H)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn", bc, decay_states, xdt)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # (B,nc,H)

    def step(h_prev, inp):
        st, dec = inp
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    if init is None:
        init = jnp.zeros((bsz, h, p_dim, bc.shape[-1]), jnp.float32)
    else:
        init = init.astype(jnp.float32)
    final, h_init = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    h_init = jnp.moveaxis(h_init, 0, 1)                       # (B,nc,H,P,N)

    # contribution of incoming state to each position
    decay_out = jnp.exp(da_cs)                                # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       cc, h_init.astype(cc.dtype), decay_out.astype(cc.dtype))
    y = (y_diag + y_off).reshape(bsz, s, h, p_dim)
    return y, final


def ssm_apply(p: dict, x: jax.Array, cfg, *, cache=None, pos=None,
              q_lens=None):
    """Mamba2 mixer. cache = {"conv": (B,K-1,C), "state": (B,H,P,N)}.

    With ``cache`` and ``pos`` the chunked scan *resumes* from the cached
    recurrent state (chunked prefill / speculative verification) instead of
    restarting — the inter-chunk recurrence is seeded with ``cache["state"]``
    and the conv context with ``cache["conv"]``.  Ragged ``q_lens`` marks
    each lane's valid length: padded positions get ``dt = 0`` (decay 1,
    zero input — the state passes through untouched) and the carried-out
    conv state is read at the lane's own length, so a ``q_lens[b] == 0``
    lane is an exact no-op on its cache.
    """
    bsz, s, _ = x.shape
    d_in = cfg.expand * cfg.d_model
    h, n, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    p_dim = d_in // h
    decode = cache is not None and s == 1 and q_lens is None
    resume = cache is not None and pos is not None and not decode

    z_all = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, z_all)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if q_lens is not None:
        valid = (jnp.arange(s)[None, :] <
                 jnp.asarray(q_lens, jnp.int32)[:, None])     # (B, S)
        dt = jnp.where(valid[..., None], dt, 0.0)

    conv_state = cache["conv"] if (decode or resume) else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state,
                                 q_lens=q_lens)
    xs, b, c = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(bsz, s, h, p_dim)
    b = b.reshape(bsz, s, g, n)
    c = c.reshape(bsz, s, g, n)

    if decode:
        a = -jnp.exp(p["A_log"])                              # (H,)
        da = jnp.exp(dt[:, 0] * a)                            # (B,H)
        rep = h // g
        bfull = jnp.repeat(b[:, 0], rep, axis=1)              # (B,H,N)
        cfull = jnp.repeat(c[:, 0], rep, axis=1)
        xdt = xs[:, 0] * dt[:, 0][..., None]                  # (B,H,P)
        state = (cache["state"] * da[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", xdt.astype(jnp.float32),
                              bfull.astype(jnp.float32)))
        y = jnp.einsum("bhpn,bhn->bhp", state, cfull.astype(jnp.float32))
        y = y[:, None] + xs * p["D"][None, None, :, None]
        new_cache = {"conv": new_conv, "state": state}
    else:
        sp = s
        pad = (-sp) % cfg.ssm_chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # SSD compute shards over heads (48 % 16 == 0 on production meshes)
        xs = constrain(xs, "batch", None, "model", None)
        dt = constrain(dt, "batch", None, "model")
        y, final = ssd_chunked(xs, dt, p["A_log"], b, c, cfg.ssm_chunk,
                               init=cache["state"] if resume else None)
        y = y[:, :s] + xs[:, :s] * p["D"][None, None, :, None]
        new_cache = None
        if cache is not None:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                         "state": final}

    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def ssm_cache_spec(cfg, batch: int):
    d_in = cfg.expand * cfg.d_model
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    return {
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.conv_kernel - 1, d_in + 2 * g * n), cfg.jnp_dtype),
        "state": jax.ShapeDtypeStruct((batch, h, d_in // h, n), jnp.float32),
    }
