"""Binarisation with straight-through estimators (paper Eq. 1, ReActNet [1]).

Training keeps full-precision *latent* weights; the forward pass sees
sign(w) (optionally scaled by the per-output-channel mean magnitude, the
XNOR-Net scaling ReActNet inherits).  Gradients flow straight through with
the usual |x| <= 1 clip on activations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def ste_sign(x: jax.Array) -> jax.Array:
    """sign(x) in {-1, +1} with straight-through gradient (clip at |x|<=1)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _ste_fwd(x):
    return ste_sign(x), x


def _ste_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_fwd, _ste_bwd)


def binarize_weights(w: jax.Array, scale: bool = True) -> jax.Array:
    """Latent fp weights -> {-a, +a} with per-output-channel scale a=mean|w|.

    The leading axis is the output-channel axis (Cout for convs, N for GEMM).
    The scale multiplies *outside* the binary core so the xnor-popcount path
    stays 1-bit; gradients reach the latent weights via STE.
    """
    wb = ste_sign(w)
    if not scale:
        return wb
    reduce_axes = tuple(range(1, w.ndim))
    alpha = jnp.mean(jnp.abs(jax.lax.stop_gradient(w)),
                     axis=reduce_axes, keepdims=True)
    return wb * alpha


def binarize_activations(x: jax.Array) -> jax.Array:
    """RSign without the learned shift (the shift lives in the model layer)."""
    return ste_sign(x)


def weight_bits(w: jax.Array) -> jax.Array:
    """{0,1} uint8 view of latent weights (1 <-> +1), for offline compression."""
    return (w >= 0).astype(jnp.uint8)
