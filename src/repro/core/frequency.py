"""Frequency-of-use analysis for bit sequences (paper §III-A, Fig. 3, Table II)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bitpack import NUM_SEQUENCES


def sequence_histogram(seqs: np.ndarray) -> np.ndarray:
    """Counts of each of the 512 sequences. Returns (512,) int64."""
    return np.bincount(
        np.asarray(seqs, dtype=np.int64).ravel(), minlength=NUM_SEQUENCES
    ).astype(np.int64)


def top_k_share(hist: np.ndarray, k: int) -> float:
    """Fraction of all sequence occurrences covered by the k most frequent."""
    total = hist.sum()
    if total == 0:
        return 0.0
    return float(np.sort(hist)[::-1][:k].sum() / total)


def ranked_sequences(hist: np.ndarray) -> np.ndarray:
    """Sequence values sorted by descending frequency (stable)."""
    # stable sort on -hist keeps the natural order among ties, which keeps the
    # node assignment deterministic across runs.
    return np.argsort(-hist, kind="stable").astype(np.uint16)


@dataclasses.dataclass(frozen=True)
class BlockStats:
    """Per-block distribution summary (one row of the paper's Table II)."""

    block: int
    total: int
    top16: float
    top64: float
    top256: float
    all_zero_one: float  # share of the all-(-1) + all-(+1) sequences

    @staticmethod
    def from_hist(block: int, hist: np.ndarray) -> "BlockStats":
        total = int(hist.sum())
        zo = float((hist[0] + hist[NUM_SEQUENCES - 1]) / total) if total else 0.0
        return BlockStats(
            block=block,
            total=total,
            top16=top_k_share(hist, 16),
            top64=top_k_share(hist, 64),
            top256=top_k_share(hist, 256),
            all_zero_one=zo,
        )


def block_table(histograms: list[np.ndarray]) -> list[BlockStats]:
    """Table II analogue: one row per basic block."""
    return [BlockStats.from_hist(i + 1, h) for i, h in enumerate(histograms)]


def synthetic_histogram(
    node_shares: tuple[float, float, float, float],
    total: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Draw a 512-bin histogram whose node-aggregate frequencies match the
    paper's published marginals (e.g. 46/24/23/5% over nodes of 32/64/64/352).

    Used to validate the compression-ratio arithmetic when ImageNet-trained
    weights are unavailable (DESIGN.md §7.1).  Within a node, mass decays
    geometrically, mimicking the measured long tail (paper Fig. 3).
    """
    sizes = (32, 64, 64, NUM_SEQUENCES - 160)
    probs = np.zeros(NUM_SEQUENCES)
    start = 0
    for share, size in zip(node_shares, sizes):
        decay = 0.96 ** np.arange(size)
        probs[start:start + size] = share * decay / decay.sum()
        start += size
    probs /= probs.sum()
    # Assign the most probable slots to "realistic" sequence values: all-zeros,
    # all-ones first (paper: ~25% combined), then random distinct values.
    order = np.concatenate(
        [[0, NUM_SEQUENCES - 1],
         rng.permutation(np.arange(1, NUM_SEQUENCES - 1))])
    hist = np.zeros(NUM_SEQUENCES, dtype=np.int64)
    draws = rng.choice(NUM_SEQUENCES, size=total, p=probs[np.argsort(order)])
    np.add.at(hist, draws, 1)
    return hist
