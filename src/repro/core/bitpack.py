"""Bit-sequence extraction and channel packing for binary kernels.

Conventions (paper §II-A, §III):
  * a binary weight/input is stored as one bit: ``1`` encodes +1, ``0`` encodes -1;
  * a *bit sequence* is the 9-bit natural-mapped value of one 3x3 channel
    (position (0,0) -> MSB / bit 8, position (2,2) -> LSB / bit 0, paper Fig. 2);
  * *channel packing* (paper Fig. 5) packs the bit at one spatial position across
    ``word_bits`` consecutive channels into one machine word.

For GEMM weights (the LM-architecture generalisation, DESIGN.md §5) a sequence is
``SEQ_BITS`` consecutive bits along the contraction axis; the identical coder and
decode kernel apply.

Everything here is offline tooling -> plain numpy.  The jnp mirrors used inside
kernels live in ``repro.kernels.ref``.
"""

from __future__ import annotations

import numpy as np

SEQ_BITS = 9          # one 3x3 channel
NUM_SEQUENCES = 1 << SEQ_BITS  # 512
WORD_BITS = 32        # packing word (int32 lanes on TPU)


# ---------------------------------------------------------------------------
# binarisation helpers (numpy; the trainable STE version lives in core.binarize)
# ---------------------------------------------------------------------------

def to_bits(x: np.ndarray) -> np.ndarray:
    """Full-precision (or +-1) tensor -> {0,1} uint8 bits. x >= 0 maps to 1."""
    return (np.asarray(x) >= 0).astype(np.uint8)


def from_bits(b: np.ndarray) -> np.ndarray:
    """{0,1} bits -> float32 {-1,+1}."""
    return np.asarray(b).astype(np.float32) * 2.0 - 1.0


# ---------------------------------------------------------------------------
# bit sequences <-> kernels
# ---------------------------------------------------------------------------

def kernel_to_sequences(w_bits: np.ndarray) -> np.ndarray:
    """(Cout, Cin, 3, 3) {0,1} -> (Cout, Cin) uint16 natural-mapped sequences."""
    if w_bits.ndim != 4 or w_bits.shape[-2:] != (3, 3):
        raise ValueError(f"expected (Cout, Cin, 3, 3), got {w_bits.shape}")
    flat = w_bits.reshape(*w_bits.shape[:2], SEQ_BITS).astype(np.uint16)
    weights = (1 << np.arange(SEQ_BITS - 1, -1, -1, dtype=np.uint16))
    return (flat * weights).sum(-1).astype(np.uint16)


def sequences_to_kernel(seqs: np.ndarray) -> np.ndarray:
    """(Cout, Cin) uint16 -> (Cout, Cin, 3, 3) {0,1} uint8."""
    shifts = np.arange(SEQ_BITS - 1, -1, -1, dtype=np.uint16)
    bits = (seqs[..., None] >> shifts) & 1
    return bits.reshape(*seqs.shape, 3, 3).astype(np.uint8)


def gemm_to_sequences(w_bits: np.ndarray) -> np.ndarray:
    """(N, K) {0,1} -> (N, ceil(K/9)) uint16, padding K with zeros (-1s).

    Padding is recorded implicitly: callers keep the true K around; padded
    positions contribute a constant correction to the xnor-popcount dot which
    ``repro.kernels.ops`` subtracts.
    """
    n, k = w_bits.shape
    k_pad = (-k) % SEQ_BITS
    if k_pad:
        w_bits = np.concatenate(
            [w_bits, np.zeros((n, k_pad), dtype=w_bits.dtype)], axis=1)
    flat = w_bits.reshape(n, -1, SEQ_BITS).astype(np.uint16)
    weights = (1 << np.arange(SEQ_BITS - 1, -1, -1, dtype=np.uint16))
    return (flat * weights).sum(-1).astype(np.uint16)


def sequences_to_gemm(seqs: np.ndarray, k: int) -> np.ndarray:
    """(N, G) uint16 -> (N, K) {0,1} uint8 dropping the zero padding."""
    shifts = np.arange(SEQ_BITS - 1, -1, -1, dtype=np.uint16)
    bits = ((seqs[..., None] >> shifts) & 1).reshape(seqs.shape[0], -1)
    return bits[:, :k].astype(np.uint8)


# ---------------------------------------------------------------------------
# channel packing (paper Fig. 5)
# ---------------------------------------------------------------------------

def pack_bits(bits: np.ndarray, axis: int = -1) -> np.ndarray:
    """Pack {0,1} bits into uint32 words along ``axis`` (bit 0 = first element).

    axis length must be a multiple of 32 (the paper packs power-of-two channel
    counts and never pads; we enforce the same).
    """
    bits = np.moveaxis(np.asarray(bits), axis, -1)
    n = bits.shape[-1]
    if n % WORD_BITS:
        raise ValueError(f"pack axis length {n} not a multiple of {WORD_BITS}")
    grouped = bits.reshape(*bits.shape[:-1], n // WORD_BITS, WORD_BITS)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    words = (grouped.astype(np.uint32) << shifts).sum(-1, dtype=np.uint32)
    return np.moveaxis(words, -1, axis)


def unpack_bits(words: np.ndarray, axis: int = -1) -> np.ndarray:
    """Inverse of :func:`pack_bits`."""
    words = np.moveaxis(np.asarray(words), axis, -1)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = ((words[..., None] >> shifts) & 1).astype(np.uint8)
    bits = bits.reshape(*bits.shape[:-2], -1)
    return np.moveaxis(bits, -1, axis)


def channel_pack_conv(w_bits: np.ndarray) -> np.ndarray:
    """(Cout, Cin, 3, 3) -> (Cout, Cin/32, 9) uint32: word j holds spatial tap j
    across 32 consecutive input channels (paper Fig. 5, R-register packing)."""
    cout, cin, kh, kw = w_bits.shape
    flat = w_bits.reshape(cout, cin, kh * kw)           # (Cout, Cin, 9)
    flat = np.moveaxis(flat, 1, -1)                     # (Cout, 9, Cin)
    packed = pack_bits(flat, axis=-1)                   # (Cout, 9, Cin/32)
    return np.moveaxis(packed, 1, -1)                   # (Cout, Cin/32, 9)


def channel_unpack_conv(words: np.ndarray) -> np.ndarray:
    """Inverse of :func:`channel_pack_conv` -> (Cout, Cin, 3, 3) uint8."""
    cout = words.shape[0]
    moved = np.moveaxis(words, -1, 1)                   # (Cout, 9, Cin/32)
    bits = unpack_bits(moved, axis=-1)                  # (Cout, 9, Cin)
    bits = np.moveaxis(bits, 1, -1)                     # (Cout, Cin, 9)
    return bits.reshape(cout, -1, 3, 3)


# ---------------------------------------------------------------------------
# GEMM packing with the sequence-aligned permutation (DESIGN.md §2/§5)
#
# K is grouped into blocks of 32 sequences x 9 bits = 288 K-positions.  Within a
# block, word j (j < 9) holds bit j of the 32 sequences -> decoding 32 sequences
# emits 9 complete words, exactly the paper's packing-unit layout.  Activations
# are packed with the same permutation so the dot product is unchanged.
# ---------------------------------------------------------------------------

SEQS_PER_BLOCK = WORD_BITS            # 32 sequences per K-block
BLOCK_K = SEQS_PER_BLOCK * SEQ_BITS   # 288 K positions per block


def pad_k(k: int) -> int:
    """K padded to a whole number of 288-bit blocks."""
    return ((k + BLOCK_K - 1) // BLOCK_K) * BLOCK_K


def pack_gemm_operand(bits: np.ndarray) -> np.ndarray:
    """(M, K) {0,1} -> (M, G, 9) uint32 sequence-aligned packed words.

    G = padded_K / 288.  Padding bits are zero; ops.py corrects for them.
    """
    m, k = bits.shape
    kp = pad_k(k)
    if kp != k:
        bits = np.concatenate(
            [bits, np.zeros((m, kp - k), dtype=bits.dtype)], axis=1)
    # (M, G, 32 seqs, 9 taps) -> word j packs tap j over the 32 sequences
    blocks = bits.reshape(m, kp // BLOCK_K, SEQS_PER_BLOCK, SEQ_BITS)
    blocks = np.moveaxis(blocks, -1, -2)                # (M, G, 9, 32)
    return pack_bits(blocks, axis=-1)[..., 0]           # (M, G, 9)


def unpack_gemm_operand(words: np.ndarray, k: int) -> np.ndarray:
    """Inverse of :func:`pack_gemm_operand` -> (M, K) uint8."""
    bits = unpack_bits(words[..., None], axis=-1)       # (M, G, 9, 32)
    bits = np.moveaxis(bits, -1, -2)                    # (M, G, 32, 9)
    return bits.reshape(bits.shape[0], -1)[:, :k]
