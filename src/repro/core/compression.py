"""End-to-end binary-kernel compression (paper §III + DESIGN.md §2).

Produces two layouts from the same node assignment:

* **stream** — one contiguous varlen bitstream (the paper's DRAM layout, used
  for storage/checkpoints and for the compression-ratio tables);
* **tiled** — the TPU-native substream-parallel layout consumed by the Pallas
  decode kernels: sequences are distributed round-robin over S substreams,
  each substream is padded to the per-tile maximum word count, and every tile
  is independently decodable.  The padding overhead is the price of
  lane-parallel decode and is reported alongside the stream ratio.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitpack, clustering, frequency, huffman
from repro.core.bitpack import SEQ_BITS

DEFAULT_SUBSTREAMS = 128      # lane dimension of the decode kernel
DEFAULT_CODES_PER_SUB = 8     # C: codes decoded per substream per tile
                              # -> tile = 1024 sequences


@dataclasses.dataclass
class TiledStream:
    """Substream-parallel compressed layout.

    words    : (n_tiles, W, S) uint32 — lane s of row w is word w of substream
               s; MSB-first bit order within each word.
    n_seqs   : true number of sequences (tail tile may be partly padding)
    s, c     : substreams per tile, codes per substream per tile
    sequence (t, c, s) of the decode output = original sequence t*S*C + c*S + s.
    """

    words: np.ndarray
    n_seqs: int
    s: int
    c: int

    @property
    def n_tiles(self) -> int:
        return self.words.shape[0]

    @property
    def w(self) -> int:
        return self.words.shape[1]

    def stored_bits(self) -> int:
        return int(self.words.size * 32)


@dataclasses.dataclass
class CompressedTensor:
    """A compressed binary weight tensor (one conv kernel or GEMM weight).

    ``tiled`` may be None when compressed with ``tiled=False`` (storage-only
    stream layout); the serving runtime re-tiles lazily via
    :func:`tile_stream` on first use.
    """

    assign: huffman.NodeAssignment
    stream_words: np.ndarray       # contiguous varlen stream (uint32)
    stream_bits: int
    tiled: TiledStream | None
    seq_shape: tuple[int, ...]     # shape of the sequence array, e.g. (Cout, Cin)
    orig_shape: tuple[int, ...]    # shape of the original bit tensor
    kind: str                      # "conv3x3" | "gemm"
    replacement: np.ndarray | None # clustering map if clustering was applied

    # -- ratios ------------------------------------------------------------
    @property
    def n_seqs(self) -> int:
        return int(np.prod(self.seq_shape))

    def ratio_stream(self) -> float:
        """Paper Table V ratio: 9-bit baseline vs varlen stream."""
        return self.n_seqs * SEQ_BITS / self.stream_bits

    def ratio_tiled(self) -> float:
        """Ratio of the TPU tiled layout (includes substream padding)."""
        return self.n_seqs * SEQ_BITS / self.tiled.stored_bits()

    def decode_tables(self) -> np.ndarray:
        return self.assign.decode_tables_flat()


def tile_stream(
    seqs: np.ndarray,
    assign: huffman.NodeAssignment,
    s: int = DEFAULT_SUBSTREAMS,
    c: int = DEFAULT_CODES_PER_SUB,
) -> TiledStream:
    flat = np.asarray(seqs, dtype=np.uint16).ravel()
    n = flat.size
    t = s * c                                     # sequences per tile
    n_tiles = (n + t - 1) // t
    # pad the tail with sequence 0 (decoded then discarded by the consumer)
    padded = np.zeros(n_tiles * t, dtype=np.uint16)
    padded[:n] = flat
    # (n_tiles, C, S): substream s consumes codes [t, :, s]
    grid = padded.reshape(n_tiles, c, s)
    vals, lens = assign.code_of(grid)             # (T, C, S) each
    # encode every (tile, substream) column at once: scatter the j-th bit of
    # every code into a per-column bit plane (12 vectorised passes)
    off = np.cumsum(lens, axis=1) - lens          # bit offset of code c
    sub_bits = lens.sum(axis=1)                   # (T, S)
    w = int(np.ceil(sub_bits.max() / 32.0))
    maxbits = w * 32
    bits = np.zeros((n_tiles, s, maxbits + 1), dtype=np.uint8)  # +1 = spill slot
    for j in range(huffman.MAX_CODE_LEN):
        valid = j < lens
        pos = np.where(valid, off + j, maxbits)
        val = np.where(valid, (vals >> (lens - 1 - j)) & 1, 0)
        np.put_along_axis(
            bits, pos.transpose(0, 2, 1), val.transpose(0, 2, 1).astype(np.uint8),
            axis=-1)
    planes = bits[..., :maxbits].reshape(n_tiles, s, w, 32)
    shifts = np.arange(31, -1, -1, dtype=np.uint32)   # MSB-first within words
    words = (planes.astype(np.uint32) << shifts).sum(-1, dtype=np.uint32)
    return TiledStream(words=words.transpose(0, 2, 1), n_seqs=n, s=s, c=c)


def compress_sequences(
    seqs: np.ndarray,
    orig_shape: tuple[int, ...],
    kind: str,
    cluster: bool = True,
    m: int = clustering.DEFAULT_M,
    n: int = clustering.DEFAULT_N,
    substreams: int = DEFAULT_SUBSTREAMS,
    codes_per_sub: int = DEFAULT_CODES_PER_SUB,
    tiled: bool = True,
) -> CompressedTensor:
    seqs = np.asarray(seqs, dtype=np.uint16)
    repl = None
    if cluster:
        seqs, repl = clustering.apply_clustering(seqs, m=m, n=n)
    hist = frequency.sequence_histogram(seqs)
    assign = huffman.assign_nodes(hist)
    stream_words, stream_bits = huffman.encode_stream(seqs, assign)
    tiled = tile_stream(seqs, assign, s=substreams, c=codes_per_sub) \
        if tiled else None
    return CompressedTensor(
        assign=assign,
        stream_words=stream_words,
        stream_bits=stream_bits,
        tiled=tiled,
        seq_shape=tuple(seqs.shape),
        orig_shape=tuple(orig_shape),
        kind=kind,
        replacement=repl,
    )


def compress_conv3x3(w_bits: np.ndarray, **kw) -> CompressedTensor:
    """(Cout, Cin, 3, 3) {0,1} -> CompressedTensor."""
    seqs = bitpack.kernel_to_sequences(w_bits)
    return compress_sequences(seqs, w_bits.shape, "conv3x3", **kw)


def compress_gemm(w_bits: np.ndarray, **kw) -> CompressedTensor:
    """(N, K) {0,1} -> CompressedTensor (9-bit grouping along K)."""
    seqs = bitpack.gemm_to_sequences(w_bits)
    return compress_sequences(seqs, w_bits.shape, "gemm", **kw)


@dataclasses.dataclass
class FusedCompressed:
    """Compressed GEMM weight in the fused-kernel block layout.

    words  : (NB, GB, W, S) uint32 — tile (nb, gb) holds weight rows
             [32nb, 32nb+32) x K-block gb (32 sequences = 288 K positions),
             row-major within the tile, round-robin over S=128 substreams.
    """

    ct: CompressedTensor
    words: np.ndarray
    n_true: int
    k_true: int

    def ratio_tiled(self) -> float:
        return self.n_true * np.ceil(self.k_true / 9) * 9 / (self.words.size * 32)


def compress_gemm_fused(w_bits: np.ndarray,
                        codes_per_sub: int = DEFAULT_CODES_PER_SUB,
                        **kw) -> FusedCompressed:
    """(N, K) {0,1} -> fused block layout for kernels.fused_decode_matmul.

    One decode tile covers ``tile_rows = 4 * codes_per_sub`` weight rows x
    one 288-bit K block.  Larger ``codes_per_sub`` amortises the 32-bit
    word-granularity padding of each substream (EXPERIMENTS.md §Perf,
    kernel iteration K2): at C=8 the per-substream quantum is 8 bits/code
    regardless of entropy; C=32 reaches ~7 bits/code.
    """
    tile_rows = 4 * codes_per_sub
    seqs = bitpack.gemm_to_sequences(w_bits)            # (N, G)
    # clustering must not flip K-padding bits (would break the xnor pad
    # correction): cluster only the complete 9-bit columns, before padding
    if kw.pop("cluster", True):
        full = w_bits.shape[1] // 9
        if full:
            sub, _ = clustering.apply_clustering(
                seqs[:, :full],
                m=kw.pop("m", clustering.DEFAULT_M),
                n=kw.pop("n", clustering.DEFAULT_N))
            seqs = np.concatenate([sub, seqs[:, full:]], axis=1)
    n, g = seqs.shape
    npad, gpad = (-n) % tile_rows, (-g) % 32
    seqs = np.pad(seqs, ((0, npad), (0, gpad)))
    nb, gb = (n + npad) // tile_rows, (g + gpad) // 32
    blocks = seqs.reshape(nb, tile_rows, gb, 32) \
        .transpose(0, 2, 1, 3).reshape(-1)
    ct = compress_sequences(
        blocks, w_bits.shape, "gemm_fused", cluster=False,
        substreams=DEFAULT_SUBSTREAMS, codes_per_sub=codes_per_sub, **kw)
    words4 = ct.tiled.words.reshape(nb, gb, ct.tiled.w, DEFAULT_SUBSTREAMS)
    return FusedCompressed(ct=ct, words=words4, n_true=n,
                           k_true=w_bits.shape[1])


def decompress_fused(fc: FusedCompressed) -> np.ndarray:
    """Reverse the fused block layout -> (N, K) bits (clustered if clustering
    was applied at compression time)."""
    ts = fc.ct.tiled
    # scalar decode per substream (test-only path): reassemble (T, C, S)
    t = fc.words.shape[0] * fc.words.shape[1]
    out = np.zeros((t, ts.c, ts.s), dtype=np.uint16)
    cols = fc.words.reshape(-1, ts.w, ts.s)
    for ti in range(t):
        for si in range(ts.s):
            out[ti, :, si] = huffman.decode_stream(
                cols[ti, :, si], ts.w * 32, fc.ct.assign, count=ts.c)
    nb, gb = fc.words.shape[:2]
    tile_rows = ts.c * 4
    seqs = out.reshape(nb, gb, tile_rows, 32).transpose(0, 2, 1, 3) \
        .reshape(nb * tile_rows, -1)
    n = fc.n_true
    g = -(-fc.k_true // 9)
    return bitpack.sequences_to_gemm(
        np.ascontiguousarray(seqs[:n, :g]), fc.k_true)


def decompress(ct: CompressedTensor) -> np.ndarray:
    """Stream-decode back to the (possibly clustered) bit tensor."""
    seqs = huffman.decode_stream(
        ct.stream_words, ct.stream_bits, ct.assign, count=ct.n_seqs
    ).reshape(ct.seq_shape)
    if ct.kind == "conv3x3":
        return bitpack.sequences_to_kernel(seqs)
    return bitpack.sequences_to_gemm(seqs, ct.orig_shape[-1])


# ---------------------------------------------------------------------------
# model-level compression (paper's 1.2x whole-model figure)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModelCompressionReport:
    per_tensor: dict[str, float]        # name -> stream ratio
    binary_bits_before: int
    binary_bits_after: int
    fp_bits: int                        # uncompressed (non-binary) parameters

    @property
    def binary_ratio(self) -> float:
        return self.binary_bits_before / max(self.binary_bits_after, 1)

    @property
    def model_ratio(self) -> float:
        before = self.binary_bits_before + self.fp_bits
        after = self.binary_bits_after + self.fp_bits
        return before / max(after, 1)


def compress_model(
    binary_tensors: dict[str, np.ndarray],
    fp_bits: int,
    kinds: dict[str, str] | None = None,
    cluster: bool = True,
) -> tuple[dict[str, CompressedTensor], ModelCompressionReport]:
    """Compress every binarized weight tensor of a model.

    ``binary_tensors``: name -> {0,1} bit tensor (4-d conv or 2-d GEMM).
    ``fp_bits``: total bits of the model's full-precision remainder
    (8-bit input/output layers, BN, PReLU — paper Table I).
    """
    out: dict[str, CompressedTensor] = {}
    ratios: dict[str, float] = {}
    before = after = 0
    for name, bits in binary_tensors.items():
        kind = (kinds or {}).get(name, "conv3x3" if bits.ndim == 4 else "gemm")
        ct = (compress_conv3x3 if kind == "conv3x3" else compress_gemm)(
            bits, cluster=cluster)
        out[name] = ct
        ratios[name] = ct.ratio_stream()
        before += ct.n_seqs * SEQ_BITS
        after += ct.stream_bits
    report = ModelCompressionReport(
        per_tensor=ratios,
        binary_bits_before=before,
        binary_bits_after=after,
        fp_bits=fp_bits,
    )
    return out, report
