"""Hamming-1 clustering of rare bit sequences (paper §III-C).

Replaces each of the N least-frequent sequences with the most-frequent
sequence from the M most-common set at Hamming distance exactly 1.  If no
such neighbour exists the sequence is kept (the paper keeps it implicitly —
its algorithm only replaces on a match).
"""

from __future__ import annotations

import numpy as np

from repro.core.bitpack import NUM_SEQUENCES, SEQ_BITS
from repro.core.frequency import ranked_sequences, sequence_histogram

# paper defaults: replace the 256 most-uncommon, candidates = top-64 set
DEFAULT_M = 64
DEFAULT_N = 256


def hamming_matrix() -> np.ndarray:
    """(512, 512) uint8 pairwise Hamming distances between 9-bit values."""
    v = np.arange(NUM_SEQUENCES, dtype=np.uint16)
    xor = v[:, None] ^ v[None, :]
    return np.array([bin(x).count("1") for x in range(NUM_SEQUENCES)],
                    dtype=np.uint8)[xor]


def build_replacement_map(
    hist: np.ndarray, m: int = DEFAULT_M, n: int = DEFAULT_N
) -> np.ndarray:
    """(512,) uint16 map value -> replacement (identity where no replacement).

    Guarantees: replacement is identity or a Hamming-1 neighbour from the
    top-``m`` set, choosing the highest-frequency neighbour (paper §III-C).
    """
    order = ranked_sequences(hist)
    present = hist > 0
    top = order[:m]
    # N least-common *present* sequences (ranked ascending by frequency)
    tail = order[present[order]][::-1][:n]
    # never fold a top-m sequence onto another (they are the cluster centres)
    tail = tail[~np.isin(tail, top)]
    repl = np.arange(NUM_SEQUENCES, dtype=np.uint16)
    hd = hamming_matrix()
    for sa in tail:
        cands = top[hd[sa, top] == 1]
        if cands.size:
            repl[sa] = cands[np.argmax(hist[cands])]
    return repl


def apply_clustering(
    seqs: np.ndarray, m: int = DEFAULT_M, n: int = DEFAULT_N,
    hist: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Replace rare sequences in ``seqs``.  Returns (new_seqs, replacement_map)."""
    if hist is None:
        hist = sequence_histogram(seqs)
    repl = build_replacement_map(hist, m, n)
    return repl[np.asarray(seqs, dtype=np.int64)], repl


def max_weight_flips(repl: np.ndarray) -> int:
    """Worst-case bit flips introduced per sequence (invariant: <= 1)."""
    v = np.arange(NUM_SEQUENCES, dtype=np.uint16)
    xor = v ^ repl
    return int(max(bin(int(x)).count("1") for x in xor))
