"""Huffman coding of bit sequences (paper §III-B).

Two coders:

* :func:`full_huffman_lengths` — a textbook Huffman build, used only as the
  compression upper bound the paper's simplified tree is traded against.
* :class:`SimplifiedCoder` — the paper's 4-node tree.  Node prefixes are
  ``0 / 10 / 110 / 111`` and node index widths ``5 / 6 / 6 / 9`` giving code
  lengths **6 / 8 / 9 / 12** exactly as in the paper (§VI).  The last node is
  the *escape node*: after prefix ``111`` the raw 9-bit sequence follows
  literally, so no fourth lookup table is needed — same code length as the
  paper's 256-entry table, strictly simpler hardware (DESIGN.md §1 note).

Encoded streams are MSB-first: the first code bit is bit 31 of uint32 word 0.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.bitpack import NUM_SEQUENCES, SEQ_BITS
from repro.core.frequency import ranked_sequences

# node capacities / prefix lengths / index widths of the simplified tree
NODE_CAPS = (32, 64, 64, NUM_SEQUENCES - 160)   # escape node holds the rest
PREFIX_LEN = (1, 2, 3, 3)                        # 0, 10, 110, 111
INDEX_BITS = (5, 6, 6, SEQ_BITS)                 # escape carries raw 9 bits
CODE_LEN = tuple(p + i for p, i in zip(PREFIX_LEN, INDEX_BITS))  # 6, 8, 9, 12
PREFIX_VAL = (0b0, 0b10, 0b110, 0b111)
MAX_CODE_LEN = CODE_LEN[-1]                      # 12


def full_huffman_lengths(hist: np.ndarray) -> np.ndarray:
    """Optimal Huffman code lengths per symbol ((512,) int32; 0 = unused)."""
    heap = [(int(c), i, (i,)) for i, c in enumerate(hist) if c > 0]
    if len(heap) == 1:
        lengths = np.zeros(NUM_SEQUENCES, dtype=np.int32)
        lengths[heap[0][1]] = 1
        return lengths
    heapq.heapify(heap)
    lengths = np.zeros(NUM_SEQUENCES, dtype=np.int32)
    tick = NUM_SEQUENCES  # tie-break counter keeps the heap total-ordered
    while len(heap) > 1:
        ca, _, sa = heapq.heappop(heap)
        cb, _, sb = heapq.heappop(heap)
        for s in sa + sb:
            lengths[s] += 1
        heapq.heappush(heap, (ca + cb, tick, sa + sb))
        tick += 1
    return lengths


def full_huffman_avg_bits(hist: np.ndarray) -> float:
    lengths = full_huffman_lengths(hist)
    total = hist.sum()
    return float((hist * lengths).sum() / total) if total else 0.0


@dataclasses.dataclass(frozen=True)
class NodeAssignment:
    """Mapping sequence value -> (node, index-within-node).

    ``node_of``  : (512,) int32 node id per sequence value
    ``index_of`` : (512,) int32 index within the node's table (for the escape
                   node this is the raw sequence value itself)
    ``tables``   : tuple of 3 uint16 arrays (sizes 32/64/64): table[i] = the
                   sequence value decoded from index i.  The escape node has
                   no table.
    """

    node_of: np.ndarray
    index_of: np.ndarray
    tables: tuple[np.ndarray, np.ndarray, np.ndarray]

    def code_of(self, seq: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(values, lengths) of the codes for an array of sequences."""
        seq = np.asarray(seq, dtype=np.int64)
        node = self.node_of[seq]
        idx = self.index_of[seq]
        plen = np.asarray(PREFIX_LEN)[node]
        ibits = np.asarray(INDEX_BITS)[node]
        pval = np.asarray(PREFIX_VAL)[node]
        return (pval.astype(np.int64) << ibits) | idx, plen + ibits

    def avg_bits(self, hist: np.ndarray) -> float:
        total = hist.sum()
        if total == 0:
            return 0.0
        lens = np.asarray(CODE_LEN)[self.node_of]
        return float((hist * lens).sum() / total)

    def compression_ratio(self, hist: np.ndarray) -> float:
        """vs. the 9-bit channel-packed baseline (paper Table V)."""
        avg = self.avg_bits(hist)
        return SEQ_BITS / avg if avg else 1.0

    def node_shares(self, hist: np.ndarray) -> np.ndarray:
        """Aggregate frequency share per node ((4,) float)."""
        total = hist.sum()
        shares = np.zeros(4)
        for n in range(4):
            shares[n] = hist[self.node_of == n].sum() / max(total, 1)
        return shares

    def decode_tables_flat(self) -> np.ndarray:
        """(160,) int32 concatenated tables for the decode kernels:
        [0:32) node0, [32:96) node1, [96:160) node2."""
        return np.concatenate([t.astype(np.int32) for t in self.tables])


def assign_nodes(hist: np.ndarray) -> NodeAssignment:
    """Fill the 4 nodes by descending frequency (paper §VI)."""
    order = ranked_sequences(hist)
    node_of = np.zeros(NUM_SEQUENCES, dtype=np.int32)
    index_of = np.zeros(NUM_SEQUENCES, dtype=np.int32)
    tables = []
    start = 0
    for n, cap in enumerate(NODE_CAPS):
        vals = order[start:start + cap]
        node_of[vals] = n
        if n < 3:
            index_of[vals] = np.arange(len(vals))
            tables.append(vals.astype(np.uint16).copy())  # rank order = table order
        else:  # escape node: the index IS the raw sequence
            index_of[vals] = vals
        start += cap
    return NodeAssignment(node_of, index_of, tuple(tables))


# ---------------------------------------------------------------------------
# stream encode / decode (vectorised numpy encode; scalar reference decode)
# ---------------------------------------------------------------------------

def encode_stream(seqs: np.ndarray, assign: NodeAssignment) -> tuple[np.ndarray, int]:
    """Encode a flat array of sequences -> (uint32 words MSB-first, nbits)."""
    vals, lens = assign.code_of(np.asarray(seqs).ravel())
    return _pack_codes(vals, lens)


def _pack_codes(vals: np.ndarray, lens: np.ndarray) -> tuple[np.ndarray, int]:
    """Vectorised variable-length bit packing (MSB-first)."""
    n = len(vals)
    if n == 0:
        return np.zeros(0, dtype=np.uint32), 0
    # (n, MAX) bit matrix, row i holds the code bits MSB-first, mask = validity
    j = np.arange(MAX_CODE_LEN)
    bitmat = (vals[:, None] >> (lens[:, None] - 1 - j)) & 1
    mask = j < lens[:, None]
    stream_bits = bitmat[mask].astype(np.uint8)  # row-major -> stream order
    nbits = int(stream_bits.size)
    pad = (-nbits) % 32
    if pad:
        stream_bits = np.concatenate([stream_bits, np.zeros(pad, np.uint8)])
    bytes_ = np.packbits(stream_bits)            # MSB-first within bytes
    words = bytes_.reshape(-1, 4).astype(np.uint32)
    words = (words[:, 0] << 24) | (words[:, 1] << 16) | (words[:, 2] << 8) | words[:, 3]
    return words.astype(np.uint32), nbits


def decode_stream(words: np.ndarray, nbits: int, assign: NodeAssignment,
                  count: int | None = None) -> np.ndarray:
    """Scalar reference decoder (tests + oracle). Returns uint16 sequences."""
    bits = np.unpackbits(
        np.concatenate([((words >> s) & 0xFF).astype(np.uint8)[:, None]
                        for s in (24, 16, 8, 0)], axis=1).ravel())[:nbits]
    out = []
    pos = 0
    while pos < nbits and (count is None or len(out) < count):
        node = 0
        if bits[pos] == 1:
            node = 1
            if bits[pos + 1] == 1:
                node = 2 if bits[pos + 2] == 0 else 3
        plen = PREFIX_LEN[node]
        ibits = INDEX_BITS[node]
        idx = 0
        for b in bits[pos + plen: pos + plen + ibits]:
            idx = (idx << 1) | int(b)
        if node < 3:
            out.append(int(assign.tables[node][idx]))
        else:
            out.append(idx)
        pos += plen + ibits
    return np.asarray(out, dtype=np.uint16)
