"""Prefix sharing: a page-granular token trie over the paged KV pool.

Serving traffic is dominated by shared prefixes (system prompts, few-shot
templates) — the same skewed-occurrence observation the paper exploits at
the kernel level.  The :class:`PrefixIndex` caches the KV pages of
completed prefills keyed by the exact token span each page covers, so a
later request whose prompt extends a cached prefix maps those physical
pages straight into its page table and skips computing the prefix
entirely.

Structure: a trie whose edges are token tuples.  A **full node** covers
exactly ``page_size`` tokens and can branch (its children extend the
prefix by the next page); a **partial node** covers the trailing
``prompt_len % page_size`` tokens of a registered prompt and is always a
leaf.  Each node owns exactly one allocator reference on its physical
page (taken via ``PageAllocator.share`` at registration, dropped at
eviction); a slot that maps a cached page at admission takes its *own*
reference, released by the normal retire path.  Copy-on-write in
:class:`~repro.runtime.scheduler.SlotPool` keys off ``refcount >= 2``, so
an index-held page can never be mutated by a slot and a page whose node
was evicted while one slot still maps it degrades to plain private
ownership.

Registration dedupes on identical token spans (the existing physical page
is kept; no second reference is taken), so re-registering a shared prefix
is free.  Lookup walks full-page children exactly, then takes the longest
common prefix into one more child (partial nodes *and* mid-page
divergence from full nodes), caps the match below the prompt length (the
last prompt token must be recomputed for first-token logits), and floors
it to a multiple of the prefill chunk size — the suffix chunks then start
on the same chunk boundaries the sharing-off run uses, which is what
makes shared serving token-identical to the oracle (locked down in
tests/test_prefix_share.py).

Eviction reuses the decode cache's :class:`FrequencyWeightedPolicy`:
every lookup hit on a node seeds its hit count as occurrence-mass prior
and bumps its aged frequency, so hot system prompts survive cold scans.
Only childless nodes are evictable (an interior page is useless without
its descendants' spans remaining reachable); dropping a leaf can expose
its parent, so eviction loops until enough allocator capacity is free.

Under the ``gathered`` backend a node additionally stores ``frag`` — host
copies of the raw-fp cache slices backing its page, snapshotted from the
registering slot's standalone prefill cache *before* install quantised
them into the pool.  They seed a future hit's standalone cache
bit-identically to what the sharing-off chunk loop would have computed,
which keeps the oracle equivalence exact under ``kv_codec="cluster"``
(the pool holds lossy codes; the standalone cache never does).  The
``pallas_paged`` mixed-step path reads the pool directly, needs no
fragments, and is exact because the codec encodes each (page, token)
independently.
"""

from __future__ import annotations

import dataclasses

from repro.runtime.decode_cache import EvictionPolicy, \
    FrequencyWeightedPolicy


@dataclasses.dataclass(eq=False)
class PrefixNode:
    """One cached physical page covering ``tokens`` (<= page_size ids)."""

    tokens: tuple
    page: int
    parent: "PrefixNode | None"
    children: dict = dataclasses.field(default_factory=dict)
    hits: int = 0
    frag: list | None = None   # gathered backend: raw-fp per-leaf slices


class PrefixIndex:
    """Token-prefix trie mapping prompt spans to shared KV pages."""

    def __init__(self, allocator, page_size: int, *, page_bytes: int = 1,
                 policy: EvictionPolicy | None = None):
        self.allocator = allocator
        self.page_size = page_size
        self.page_bytes = max(int(page_bytes), 1)
        self.policy = policy if policy is not None \
            else FrequencyWeightedPolicy()
        self._root = PrefixNode(tokens=(), page=-1, parent=None)

    # -- introspection ------------------------------------------------------
    def _nodes(self):
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    @property
    def n_nodes(self) -> int:
        return sum(1 for _ in self._nodes())

    @property
    def tokens_cached(self) -> int:
        return sum(len(n.tokens) for n in self._nodes())

    # -- lookup -------------------------------------------------------------
    def lookup(self, prompt, limit: int, align: int):
        """Longest cached prefix of ``prompt`` -> (nodes, matched tokens).

        ``limit`` caps the raw match (callers pass ``prompt_len - 1`` so
        the last prompt token is always recomputed — its logits produce
        the first generated token); the match is then floored to a
        multiple of ``align`` (the prefill chunk size) so the remaining
        chunks land on the exact boundaries the sharing-off run uses.
        The returned nodes back positions ``[0, matched)`` page by page;
        ``matched == 0`` means no usable hit.
        """
        P = self.page_size
        toks = tuple(int(t) for t in prompt)
        node, path, i = self._root, [], 0
        while len(toks) - i >= P:
            child = node.children.get(toks[i:i + P])
            if child is None or len(child.tokens) < P:
                break
            path.append(child)
            node = child
            i += P
        # one more page of partial match: the child (full or partial)
        # sharing the longest common prefix with the remainder
        best, best_node = 0, None
        for child in node.children.values():
            n = 0
            for a, b in zip(child.tokens, toks[i:]):
                if a != b:
                    break
                n += 1
            if n > best:
                best, best_node = n, child
        matched = min(i + best, limit)
        matched -= matched % max(align, 1)
        if matched <= 0:
            return [], 0
        n_pages = -(-matched // P)
        if best_node is not None and n_pages > len(path):
            path.append(best_node)
        del path[n_pages:]
        return path, matched

    def hit(self, nodes) -> None:
        """Bump every mapped node: its hit count is re-seeded as the
        eviction policy's occurrence-mass prior (prefix hits *are* the
        paper's skewed sequence frequency) on top of the aged bump."""
        for node in nodes:
            node.hits += 1
            self.policy.seed(node, float(node.hits))
            self.policy.on_hit(node)

    # -- registration -------------------------------------------------------
    def register(self, prompt, row, frags=None,
                 allow_partial: bool = True) -> bool:
        """Insert ``prompt``'s pages (page-table ``row``) into the trie,
        taking one allocator reference per *new* node; spans already
        cached dedupe onto their existing physical page.  ``frags[j]``
        (gathered backend) is the list of raw-fp per-leaf slices backing
        page ``j``.  Returns True iff a new partial boundary node was
        created (the caller funds that page's future copy-on-write)."""
        P = self.page_size
        toks = tuple(int(t) for t in prompt)
        node, new_partial = self._root, False
        n_full = len(toks) // P
        for j in range(n_full):
            key = toks[j * P:(j + 1) * P]
            child = node.children.get(key)
            if child is None:
                child = self._insert(node, key, int(row[j]),
                                     frags[j] if frags else None)
            node = child
        rem = toks[n_full * P:]
        if rem and allow_partial and rem not in node.children:
            self._insert(node, rem, int(row[n_full]),
                         frags[n_full] if frags else None)
            new_partial = True
        return new_partial

    def _insert(self, parent, key, page, frag) -> PrefixNode:
        child = PrefixNode(tokens=key, page=self.allocator.share(page),
                           parent=parent, frag=frag)
        parent.children[key] = child
        self.policy.on_insert(child, self.page_bytes)
        return child

    # -- eviction -----------------------------------------------------------
    def _drop(self, node) -> None:
        del node.parent.children[node.tokens]
        self.policy.on_remove(node)
        self.allocator.release([node.page])

    def evict_until(self, need: int) -> int:
        """Drop childless nodes in ascending eviction-score order until
        ``allocator.available() >= need`` -> nodes dropped.  Releasing a
        node only frees its page when no slot still maps it, so the loop
        keeps going past still-mapped victims; dropping a leaf can expose
        its parent as the next candidate."""
        dropped = 0
        while self.allocator.available() < need:
            victim = next((n for n in self.policy.order()
                           if not n.children), None)
            if victim is None:
                break
            self._drop(victim)
            dropped += 1
        return dropped

    def clear(self) -> int:
        """Drop every node (releasing the index's page references)."""
        dropped = 0
        while True:
            leaves = [n for n in self._nodes() if not n.children]
            if not leaves:
                break
            for node in leaves:
                self._drop(node)
                dropped += 1
        self.policy.clear()
        return dropped
