"""Observability primitives: histograms, a metrics registry, and tracing.

The paper's argument is quantitative — §V's 1.32x memory / 1.35x
performance wins are claims about *distributions* of accesses and
latencies — but lifetime-average counters cannot see a distribution:
a TTFT p99 regression, a decode-stall spike, or a cache-hit collapse
under churn all vanish into the mean.  This module is the telemetry
layer the serving runtime records into:

  * :class:`Histogram` — fixed-bucket log-scale latency histograms with
    p50/p90/p99 estimation (bucket edges grow geometrically, so one
    bucket is a constant *relative* error anywhere in the range);
  * :class:`MetricsRegistry` — a pull-based registry: every counter /
    gauge / histogram is registered by name with a getter and rendered
    on demand as Prometheus text-exposition format
    (:meth:`MetricsRegistry.render`; :func:`parse_prom` validates it);
  * :class:`Tracer` — per-request lifecycle span trees (``queued ->
    admitted -> prefill_chunk[i] -> decode -> retired``) plus
    scheduler/weight-store phase spans, exportable as Chrome-trace JSON
    (loadable in ``chrome://tracing`` / Perfetto) and as JSONL events;
  * :class:`Telemetry` — the facade the runtime threads around: a
    lightweight ``timed(phase)`` context manager that records a phase
    histogram and (when tracing) a span, so the trace shows where an
    iteration's wall clock actually went.

Cost discipline: the default recorder is :data:`NULL_TELEMETRY`, whose
``timed`` returns one shared no-op context manager and whose tracer
drops everything — serving with telemetry disabled does no extra work
beyond an attribute read, and telemetry never influences scheduling, so
generated tokens are identical with it on or off (tested).
"""

from __future__ import annotations

import bisect
import contextlib
import json
import math
import re
import time

# Chrome-trace "process" ids: one per track family so Perfetto groups
# request lifecycles separately from engine phases.
PID_REQUEST = 1     # one thread (tid) per request id
PID_ENGINE = 2      # scheduler / weight-store phase spans, tid 0

_US = 1e6           # chrome trace timestamps are microseconds


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

class Histogram:
    """Fixed-bucket log-scale histogram (values in seconds by default).

    Bucket upper edges are ``lo * 10**(i / per_decade)`` — geometric
    growth, so percentile estimates carry a constant *relative* error of
    one bucket ratio (``10**(1/per_decade)``, ~1.58x at the default 5
    buckets per decade) anywhere in the range.  Values at or below the
    smallest edge land in bucket 0; values above the largest edge land
    in the overflow bucket and are reported as the observed max.
    Recording is a bisect + three adds — cheap enough to stay on in the
    scheduler hot loop.
    """

    __slots__ = ("bounds", "counts", "n", "total", "min", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 120.0,
                 per_decade: int = 5):
        n = int(math.ceil(per_decade * math.log10(hi / lo))) + 1
        self.bounds: tuple = tuple(lo * 10 ** (i / per_decade)
                                   for i in range(n))
        self.counts: list[int] = [0] * (n + 1)      # +1: overflow bucket
        self.n = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.n += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, p: float) -> float:
        """Estimated ``p``-th percentile (log-interpolated within the
        bucket holding that rank; clamped to the observed min/max, so
        the estimate always lies inside the value range)."""
        if not self.n:
            return 0.0
        rank = max(1, math.ceil(p / 100.0 * self.n))
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= rank:
                if i == len(self.bounds):       # overflow bucket
                    return self.max
                hi = self.bounds[i]
                lo = self.bounds[i - 1] if i else \
                    hi / (self.bounds[1] / self.bounds[0])
                frac = (rank - cum) / c
                est = lo * (hi / lo) ** frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def percentiles(self, *ps: float) -> tuple:
        return tuple(self.percentile(p) for p in ps)


# ---------------------------------------------------------------------------
# pull-based metrics registry -> Prometheus text exposition
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Name -> getter registry rendered as Prometheus text exposition.

    Pull-based: registration stores a callable, not a value, so one
    registry built at startup always renders current counters.  Names
    get a ``namespace_`` prefix and must be valid Prometheus metric
    names; counters should end ``_total`` by convention (the tests
    assert monotonicity for every ``_total``/``_count``/``_bucket``
    sample across scrapes).
    """

    _NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._metrics: list[tuple] = []     # (name, kind, getter, help)

    def _add(self, name: str, kind: str, getter, help_: str) -> None:
        full = f"{self.namespace}_{name}"
        if not self._NAME_RE.match(full):
            raise ValueError(f"invalid metric name {full!r}")
        if any(m[0] == full for m in self._metrics):
            raise ValueError(f"metric {full!r} already registered")
        self._metrics.append((full, kind, getter, help_))

    def counter(self, name: str, getter, help_: str = "") -> None:
        self._add(name, "counter", getter, help_)

    def gauge(self, name: str, getter, help_: str = "") -> None:
        self._add(name, "gauge", getter, help_)

    def histogram(self, name: str, hist: Histogram | "callable",
                  help_: str = "") -> None:
        getter = hist if callable(hist) else (lambda: hist)
        self._add(name, "histogram", getter, help_)

    def sample(self) -> dict:
        """Scalar samples (counters + gauges) by full name — the
        interval-snapshot primitive."""
        return {name: float(getter())
                for name, kind, getter, _ in self._metrics
                if kind != "histogram"}

    def render(self) -> str:
        """Prometheus text-exposition format (0.0.4)."""
        lines = []
        for name, kind, getter, help_ in self._metrics:
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {kind}")
            if kind != "histogram":
                lines.append(f"{name} {_fmt_value(float(getter()))}")
                continue
            h: Histogram = getter()
            cum = 0
            for bound, c in zip(h.bounds, h.counts):
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt_value(bound)}"}} '
                             f"{cum}")
            lines.append(f'{name}_bucket{{le="+Inf"}} {h.n}')
            lines.append(f"{name}_sum {_fmt_value(h.total)}")
            lines.append(f"{name}_count {h.n}")
        return "\n".join(lines) + "\n"


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)$")


def parse_prom(text: str) -> dict:
    """Parse Prometheus text exposition -> ``{(name, labels): value}``.

    ``labels`` is the raw label string (``""`` when absent), so
    histogram buckets keep distinct keys.  Raises ``ValueError`` on any
    malformed line — this is the validator CI and the tests run over
    every ``.prom`` dump, so a rendering regression cannot land.
    """
    out: dict = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"malformed prometheus line {lineno}: {line!r}")
        try:
            value = float(m.group("value"))
        except ValueError:
            raise ValueError(
                f"malformed prometheus value on line {lineno}: "
                f"{line!r}") from None
        out[(m.group("name"), m.group("labels") or "")] = value
    return out


# ---------------------------------------------------------------------------
# tracing: per-request span trees + engine phase spans
# ---------------------------------------------------------------------------

class Tracer:
    """Event recorder exporting Chrome-trace JSON and JSONL.

    Events live in one flat list in the Chrome ``traceEvents`` shape:
    complete spans (``ph="X"``: name, ts, dur) and instants
    (``ph="i"``).  Tracks are ``(pid, tid)`` pairs — requests get
    ``(PID_REQUEST, rid)`` so each request renders as its own lane,
    engine phases share ``(PID_ENGINE, 0)`` and nest by containment
    (the runtime is single-threaded and synchronous).  Timestamps are
    microseconds relative to tracer construction.
    """

    enabled = True

    def __init__(self):
        self.t0 = time.monotonic()
        self.events: list[dict] = []
        self._track_names: dict = {}

    def now(self) -> float:
        return time.monotonic()

    def _ts(self, t: float) -> float:
        return (t - self.t0) * _US

    def complete(self, pid: int, tid: int, name: str, t0: float,
                 t1: float, **args) -> None:
        """One complete span [t0, t1] (monotonic seconds)."""
        self.events.append({
            "ph": "X", "name": name, "pid": pid, "tid": tid,
            "ts": self._ts(t0), "dur": max((t1 - t0) * _US, 0.0),
            "args": args})

    def instant(self, pid: int, tid: int, name: str,
                t: float | None = None, **args) -> None:
        self.events.append({
            "ph": "i", "s": "t", "name": name, "pid": pid, "tid": tid,
            "ts": self._ts(self.now() if t is None else t), "args": args})

    @contextlib.contextmanager
    def span(self, pid: int, tid: int, name: str, **args):
        t0 = self.now()
        try:
            yield
        finally:
            self.complete(pid, tid, name, t0, self.now(), **args)

    def name_track(self, pid: int, tid: int, name: str) -> None:
        self._track_names[(pid, tid)] = name

    # -- export ------------------------------------------------------------
    def chrome(self) -> dict:
        """Chrome-trace JSON object (load in chrome://tracing or
        https://ui.perfetto.dev)."""
        meta = []
        pids = {pid for pid, _ in self._track_names} | \
            {e["pid"] for e in self.events}
        proc_names = {PID_REQUEST: "requests", PID_ENGINE: "engine"}
        for pid in sorted(pids):
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0,
                         "args": {"name": proc_names.get(pid, str(pid))}})
        for (pid, tid), name in sorted(self._track_names.items()):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": name}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms"}

    def write_chrome(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome(), f)

    def write_jsonl(self, path) -> None:
        """One JSON event per line (grep/jq-friendly event log)."""
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e) + "\n")


class NullTracer:
    """Drops everything; ``enabled`` lets hot paths skip arg building."""

    enabled = False

    def now(self) -> float:
        return time.monotonic()

    def complete(self, *args, **kwargs) -> None:
        pass

    def instant(self, *args, **kwargs) -> None:
        pass

    def span(self, *args, **kwargs):
        return _NULL_CTX

    def name_track(self, *args, **kwargs) -> None:
        pass


NULL_TRACER = NullTracer()
_NULL_CTX = contextlib.nullcontext()


# ---------------------------------------------------------------------------
# the facade the runtime threads through
# ---------------------------------------------------------------------------

class _Timed:
    """``timed(phase)`` context: phase histogram + (if tracing) a span."""

    __slots__ = ("tel", "phase", "args", "t0")

    def __init__(self, tel: "Telemetry", phase: str, args: dict):
        self.tel = tel
        self.phase = phase
        self.args = args

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        t1 = time.monotonic()
        tel = self.tel
        hist = tel.phases.get(self.phase)
        if hist is None:
            hist = tel.phases[self.phase] = Histogram()
        hist.record(t1 - self.t0)
        if tel.tracer.enabled:
            tel.tracer.complete(PID_ENGINE, 0, self.phase, self.t0, t1,
                                **self.args)
        return False


class Telemetry:
    """Request tracing + phase timing, threaded through the runtime.

    ``trace=True`` records per-request lifecycle spans and engine phase
    spans into a :class:`Tracer`; ``trace=False`` keeps only the cheap
    per-phase histograms (still rendered into the Prometheus dump).
    The runtime default is :data:`NULL_TELEMETRY`, which records
    nothing at all.
    """

    def __init__(self, trace: bool = False):
        self.tracer: Tracer | NullTracer = Tracer() if trace \
            else NULL_TRACER
        self.phases: dict[str, Histogram] = {}

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    def timed(self, phase: str, **args) -> _Timed:
        """Time a phase: records into ``phases[phase]`` and, when
        tracing, emits an engine-track span."""
        return _Timed(self, phase, args)


class NullTelemetry:
    """The no-op default: ``timed`` hands back one shared null context,
    so a disabled run's overhead is a method call returning a constant."""

    tracing = False
    tracer = NULL_TRACER
    phases: dict = {}

    def timed(self, phase: str, **args):
        return _NULL_CTX


NULL_TELEMETRY = NullTelemetry()
