"""Serving counters and the periodic stats line.

One ServeMetrics instance per engine; the scheduler ticks it every
admission and decode step and asks for a stats line every ``log_every``
steps.  The cache-side counters (hits / misses / bytes) live on the
DecodeTileCache itself and are merged into the line here, so one string
answers the questions the paper's evaluation asks: how fast, how full the
slots run, how often the decode cache hits, and how many HBM bytes the
compressed path avoided streaming.

Slot-level accounting: ``slot_steps`` counts (decode step x active slot)
pairs and ``capacity_steps`` counts (decode step x slot) pairs, so
``occupancy()`` is the fraction of decode lanes that carried a live
request — the quantity slot-level continuous batching raises over
wave-granular scheduling (waves idle finished lanes until the wave
drains).

Chunked-prefill accounting: ``prefill_chunks`` / ``prefill_chunk_tokens``
count the chunks pushed through ``prefill_chunk`` and
``decode_stall_s`` accumulates chunk time spent while active slots had
decode work waiting — the latency cost that the per-iteration prefill
token budget bounds.  Page accounting (paged KV pools only):
``pages_in_use`` / ``pages_total`` are last-step gauges and
``page_occupancy()`` is the mean pool fraction holding live request
state — the memory short requests stop paying under paged lanes.

Attention-backend accounting: ``kv_gather_bytes`` counts the cache bytes
the decode hot path copied through the per-step page gather/scatter
(the ``gathered`` backend's two full view copies per step) and
``kv_gather_bytes_avoided`` the bytes the in-kernel ``pallas_paged``
backend did *not* copy.  The same accounting extends to prefill:
``kv_prefill_gather_bytes`` counts the cache bytes prefill moved between
the pools and standalone/batch-1 caches (the gathered oracle's
install-time scatter of a freshly prefilled cache into the slot's pages
and lane) and ``kv_prefill_gather_bytes_avoided`` the install copies the
mixed-step path never performed (its chunks write straight into the
pools).  A paged-kernel mixed-step run must report **both** gather
counters == 0 — those zeros are the acceptance criterion for killing the
per-step page copies on the decode *and* prefill paths, and tests assert
them.

Observability: latency *distributions* ride beside the counters —
log-bucket histograms (``runtime.telemetry.Histogram``) for TTFT, time
per output token, end-to-end latency, prefill-chunk duration, and
decode-step duration, with p50/p99 in the stats line.  The periodic
stats line reports rates over the *last window* (interval-delta
snapshots via :meth:`ServeMetrics.window`), not lifetime averages; the
lifetime counters remain for the final summary.  Everything is
exportable as Prometheus text exposition through
:meth:`ServeMetrics.render_prom`, including the decode-cache /
weight-store counters and telemetry phase histograms when provided.
"""

from __future__ import annotations

import dataclasses
import time

from repro.runtime.telemetry import Histogram, MetricsRegistry


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024.0 or unit == "TB":
            return f"{n:.1f} {unit}"
        n /= 1024.0
    return f"{n:.1f} TB"


@dataclasses.dataclass
class ServeMetrics:
    tokens_generated: int = 0
    requests_completed: int = 0
    requests_admitted: int = 0
    prefills: int = 0
    decode_steps: int = 0
    slot_steps: int = 0        # sum over decode steps of active slots
    capacity_steps: int = 0    # sum over decode steps of total slots
    waves: int = 0             # admission rounds (wave mode only)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_chunks: int = 0            # chunked-prefill chunk count
    prefill_chunk_tokens: int = 0      # prompt tokens pushed through chunks
    decode_stall_s: float = 0.0        # chunk time while decoders waited
    pages_in_use: int = 0              # KV page gauges (paged pools only;
    pages_total: int = 0               # last observed decode step)
    page_use_steps: int = 0            # sum over steps of pages_in_use
    page_capacity_steps: int = 0       # sum over steps of pages_total
    kv_gather_bytes: int = 0           # per-step KV page gather/scatter
    #                                    copies on the decode hot path
    #                                    (gathered backend; 0 under
    #                                    pallas_paged — the acceptance
    #                                    signal that the kernel backend
    #                                    truly killed the copies)
    kv_gather_bytes_avoided: int = 0   # copies the pallas_paged backend
    #                                    skipped vs the gathered oracle
    kv_prefill_gather_bytes: int = 0   # prefill-path cache copies (the
    #                                    gathered oracle's install-time
    #                                    scatter; 0 under mixed-step
    #                                    pallas_paged — chunks write
    #                                    straight into the pools)
    kv_prefill_gather_bytes_avoided: int = 0  # install copies mixed-step
    #                                    prefill skipped vs the oracle
    kv_codec_bytes_fp: int = 0         # per-step resident page bytes the
    #                                    pool would hold uncompressed
    #                                    (kv_codec="cluster" only)
    kv_codec_bytes_resident: int = 0   # per-step resident page bytes the
    #                                    codec pool actually holds (int8
    #                                    codes + per-token f32 scales)
    kv_bytes_avoided: int = 0          # fp - resident: HBM bytes the KV
    #                                    codec kept out of the pool
    kv_codec_error_bound: float = 0.0  # worst elementwise reconstruction
    #                                    error bound seen (max scale / 254)
    kernel_qblock_rounded: int = 0     # mixed steps whose tuned q_block
    #                                    did not divide the step's Q and
    #                                    silently rounded to gcd(Q, qb)
    prefix_hits: int = 0               # admissions that mapped a cached
    #                                    prefix (prefix_share only)
    prefix_tokens_reused: int = 0      # prompt tokens served straight
    #                                    from shared pages — prefill work
    #                                    for them was exactly zero
    prefill_chunks_avoided: int = 0    # prefill chunks never executed
    #                                    because their tokens were mapped
    prefix_cow_copies: int = 0         # shared pages copy-on-write'd
    #                                    when a request diverged
    prefix_evictions: int = 0          # index entries dropped under
    #                                    reservation pressure
    shared_pages: int = 0              # pages referenced >1x (last-step
    shared_page_steps: int = 0         # gauge; sum over steps for mean)
    spec_rounds: int = 0               # (speculative round x slot) pairs
    #                                    that carried >=1 draft token
    spec_draft_tokens: int = 0         # draft tokens proposed to verify
    spec_accepted_tokens: int = 0      # drafts the model's argmax agreed
    #                                    with (emitted beyond the 1/step
    #                                    baseline — the speculation win)
    spec_rejected_tokens: int = 0      # drafts rolled back
    _t0: float = dataclasses.field(default_factory=time.monotonic)
    # latency distributions (log-bucket histograms; seconds).  Lifetime
    # averages hide tails — the paper's wins are distribution claims, so
    # the stats line and summary report p50/p90/p99 from these.
    ttft_hist: Histogram = dataclasses.field(default_factory=Histogram)
    tpot_hist: Histogram = dataclasses.field(default_factory=Histogram)
    e2e_hist: Histogram = dataclasses.field(default_factory=Histogram)
    chunk_hist: Histogram = dataclasses.field(default_factory=Histogram)
    step_hist: Histogram = dataclasses.field(default_factory=Histogram)
    # interval-snapshot baseline for windowed stats lines (the periodic
    # line reports rates over the last window, not lifetime averages —
    # a burst an hour ago must not make the current line look fast)
    _win: dict = dataclasses.field(default_factory=dict)

    # -- recording ---------------------------------------------------------
    def record_admit(self, n_requests: int, dt: float,
                     tokens: int = 0) -> None:
        """One admission: batch-1 prefill of ``n_requests`` requests;
        ``tokens`` counts the first generated token(s) prefill produced."""
        self.requests_admitted += n_requests
        self.prefills += n_requests
        self.prefill_s += dt
        self.tokens_generated += tokens

    def record_wave(self) -> None:
        """One drain-then-admit round (wave-mode scheduling only)."""
        self.waves += 1

    def record_prefill_chunk(self, n_tokens: int, dt: float,
                             stalled: bool = False) -> None:
        """One prompt chunk through ``prefill_chunk``; ``stalled`` marks
        chunks that ran while other slots had decode work waiting (their
        time is the decode-latency cost chunking is bounding)."""
        self.prefill_chunks += 1
        self.prefill_chunk_tokens += n_tokens
        self.prefill_s += dt
        self.chunk_hist.record(dt)
        if stalled:
            self.decode_stall_s += dt

    def record_pages(self, in_use: int, total: int) -> None:
        """KV page-pool gauge after a decode step (paged pools only)."""
        self.pages_in_use = in_use
        self.pages_total = total
        self.page_use_steps += in_use
        self.page_capacity_steps += total

    def record_kv_gather(self, moved: int, avoided: int) -> None:
        """KV cache bytes copied by this decode step's page
        gather/scatter (``moved``; the gathered backend's two full cache
        copies) and bytes those copies *would* have been under the
        gathered oracle but were not (``avoided``; the pallas_paged
        backend, whose kernel walks the page table in place)."""
        self.kv_gather_bytes += moved
        self.kv_gather_bytes_avoided += avoided

    def record_prefill_gather(self, moved: int, avoided: int) -> None:
        """Prefill-path cache bytes copied between pools and standalone
        caches (``moved``; the gathered oracle scatters each freshly
        prefilled batch-1 cache into the slot's pages + lane at install)
        and install copies the mixed-step path skipped because its chunks
        were written straight into the pools (``avoided``)."""
        self.kv_prefill_gather_bytes += moved
        self.kv_prefill_gather_bytes_avoided += avoided

    def record_kv_codec(self, fp_bytes: int, resident_bytes: int) -> None:
        """Resident KV pool bytes after one decode step under
        ``kv_codec="cluster"``: what the live pages would weigh at fp
        (``fp_bytes``) vs what the compressed pool actually holds
        (``resident_bytes``); the difference accumulates into
        ``kv_bytes_avoided``."""
        self.kv_codec_bytes_fp += fp_bytes
        self.kv_codec_bytes_resident += resident_bytes
        self.kv_bytes_avoided += fp_bytes - resident_bytes

    def record_kernel_qblock_rounded(self) -> None:
        """One mixed step served with a gcd-rounded ``q_block`` (the
        tuned block width did not divide this step's ``Q``)."""
        self.kernel_qblock_rounded += 1

    def record_prefix_hit(self, tokens: int, chunks_avoided: int) -> None:
        """One admission that mapped a cached prefix: ``tokens`` prompt
        positions rode shared pages (zero prefill work) and
        ``chunks_avoided`` prefill chunks were never executed."""
        self.prefix_hits += 1
        self.prefix_tokens_reused += tokens
        self.prefill_chunks_avoided += chunks_avoided

    def record_prefix_cow(self) -> None:
        """One shared page copied on write (request diverged mid-page)."""
        self.prefix_cow_copies += 1

    def record_prefix_evictions(self, n: int) -> None:
        """Prefix-index entries dropped under reservation pressure."""
        self.prefix_evictions += n

    def record_shared_pages(self, n: int) -> None:
        """Shared-page occupancy gauge after one decode step."""
        self.shared_pages = n
        self.shared_page_steps += n

    def record_kv_codec_error(self, bound: float) -> None:
        """Worst-case elementwise KV reconstruction error bound of the
        resident pool (monotone max across runs)."""
        self.kv_codec_error_bound = max(self.kv_codec_error_bound, bound)

    def kv_capacity_multiplier(self) -> float:
        """Effective-capacity multiplier of the KV codec: fp bytes per
        resident byte (1.0 when the codec is off or nothing resided)."""
        return self.kv_codec_bytes_fp / self.kv_codec_bytes_resident \
            if self.kv_codec_bytes_resident else 1.0

    def record_decode_step(self, n_tokens: int, dt: float,
                           n_slots: int = 0) -> None:
        self.decode_steps += 1
        self.tokens_generated += n_tokens
        self.slot_steps += n_tokens
        self.capacity_steps += n_slots
        self.decode_s += dt
        self.step_hist.record(dt)

    def record_spec(self, proposed: int, accepted: int) -> None:
        """One slot's speculative verification: ``proposed`` draft tokens
        scored, ``accepted`` of them matching the model's own argmax
        chain (the rest were rolled back).  No-op when nothing was
        proposed — a slot the drafter skipped is a plain decode step."""
        if proposed <= 0:
            return
        self.spec_rounds += 1
        self.spec_draft_tokens += proposed
        self.spec_accepted_tokens += accepted
        self.spec_rejected_tokens += proposed - accepted

    def spec_acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the verifier accepted."""
        return self.spec_accepted_tokens / self.spec_draft_tokens \
            if self.spec_draft_tokens else 0.0

    def record_completed(self, n_requests: int) -> None:
        self.requests_completed += n_requests

    def record_ttft(self, dt: float) -> None:
        """Time to first token of one request (submit -> first token)."""
        self.ttft_hist.record(dt)

    def record_request_done(self, req) -> None:
        """Retire-time latencies of one finished request: end-to-end
        (submit -> done) and time-per-output-token (the decode-phase
        mean: first token -> done over the tokens after the first)."""
        if req.t_done is None or req.t_submit is None:
            return
        self.e2e_hist.record(req.t_done - req.t_submit)
        if req.t_first is not None and len(req.generated) > 1:
            self.tpot_hist.record((req.t_done - req.t_first)
                                  / (len(req.generated) - 1))

    # -- derived -----------------------------------------------------------
    def tokens_per_s(self) -> float:
        """Decode throughput: decode-step tokens over decode time (first
        tokens come out of prefill and are excluded from both sides)."""
        dt = self.decode_s
        return self.slot_steps / dt if dt > 0 else 0.0

    def ms_per_token(self) -> float:
        steps = self.decode_steps
        return self.decode_s / steps * 1000.0 if steps else 0.0

    def occupancy(self) -> float:
        """Fraction of decode-lane steps that carried an active request."""
        return self.slot_steps / self.capacity_steps \
            if self.capacity_steps else 0.0

    def page_occupancy(self) -> float:
        """Mean fraction of the KV page pool holding live request state
        (the memory short requests stop paying under paged lanes)."""
        return self.page_use_steps / self.page_capacity_steps \
            if self.page_capacity_steps else 0.0

    def prefill_chunk_ms(self) -> float:
        """Mean milliseconds per prefill chunk (chunked prefill only)."""
        return self.prefill_s / self.prefill_chunks * 1000.0 \
            if self.prefill_chunks else 0.0

    # -- interval windows --------------------------------------------------
    _RATE_FIELDS = ("tokens_generated", "slot_steps", "decode_steps",
                    "capacity_steps", "decode_s", "prefill_s",
                    "requests_completed", "requests_admitted")

    def _sample(self, cache=None) -> dict:
        snap = {f: getattr(self, f) for f in self._RATE_FIELDS}
        snap["cache_hits"] = cache.hits if cache is not None else 0
        snap["cache_misses"] = cache.misses if cache is not None else 0
        snap["t"] = time.monotonic()
        return snap

    def window(self, cache=None) -> dict:
        """Counter deltas since the previous :meth:`window` call (the
        first window spans the metrics' whole lifetime), and the
        baseline is advanced — the periodic stats line reports *rates
        over the last window*, so a burst long past cannot keep the
        current line looking fast.  Lifetime numbers stay available on
        the counters themselves for the final summary."""
        cur = self._sample(cache)
        delta = {k: cur[k] - self._win.get(k, 0.0 if k == "t" else 0)
                 for k in cur}
        if not self._win:
            delta["t"] = cur["t"] - self._t0
        self._win.clear()
        self._win.update(cur)
        return delta

    def stats_line(self, cache=None) -> str:
        w = self.window(cache)
        tok_s = w["slot_steps"] / w["decode_s"] if w["decode_s"] > 0 else 0.0
        ms_step = w["decode_s"] / w["decode_steps"] * 1000.0 \
            if w["decode_steps"] else 0.0
        parts = [
            f"tokens {self.tokens_generated}",
            f"{tok_s:.1f} tok/s",
            f"{ms_step:.1f} ms/step",
            f"reqs {self.requests_completed}/{self.requests_admitted}",
        ]
        if w["capacity_steps"]:
            parts.append(
                f"occupancy "
                f"{w['slot_steps'] / w['capacity_steps'] * 100:.0f}%")
        if self.prefill_chunks:
            parts.append(f"chunks {self.prefill_chunks} "
                         f"({self.prefill_chunk_ms():.1f} ms, "
                         f"stall {self.decode_stall_s:.2f}s)")
        if self.pages_total:
            parts.append(f"pages {self.pages_in_use}/{self.pages_total} "
                         f"({self.page_occupancy() * 100:.0f}% mean)")
        if self.kv_gather_bytes or self.kv_gather_bytes_avoided:
            parts.append(
                f"kv gather {_fmt_bytes(self.kv_gather_bytes)} "
                f"(avoided {_fmt_bytes(self.kv_gather_bytes_avoided)})")
        if self.kv_prefill_gather_bytes or \
                self.kv_prefill_gather_bytes_avoided:
            parts.append(
                f"prefill gather "
                f"{_fmt_bytes(self.kv_prefill_gather_bytes)} "
                f"(avoided "
                f"{_fmt_bytes(self.kv_prefill_gather_bytes_avoided)})")
        if self.kv_bytes_avoided:
            parts.append(
                f"kv codec {self.kv_capacity_multiplier():.2f}x "
                f"(avoided {_fmt_bytes(self.kv_bytes_avoided)})")
        if self.kernel_qblock_rounded:
            parts.append(
                f"qblock rounded {self.kernel_qblock_rounded}")
        if self.prefix_hits:
            parts.append(
                f"prefix {self.prefix_hits} hits "
                f"({self.prefix_tokens_reused} toks reused, "
                f"{self.prefill_chunks_avoided} chunks avoided, "
                f"{self.prefix_cow_copies} cow)")
        if self.spec_rounds:
            parts.append(
                f"spec {self.spec_accepted_tokens}/"
                f"{self.spec_draft_tokens} drafts accepted "
                f"({self.spec_acceptance_rate() * 100:.0f}%)")
        if self.ttft_hist.n:
            p50, p99 = self.ttft_hist.percentiles(50, 99)
            parts.append(f"ttft p50 {p50 * 1000:.0f}ms p99 {p99 * 1000:.0f}ms")
        if self.tpot_hist.n:
            p50, p99 = self.tpot_hist.percentiles(50, 99)
            parts.append(f"tpot p50 {p50 * 1000:.1f}ms p99 {p99 * 1000:.1f}ms")
        if cache is not None:
            acc = w["cache_hits"] + w["cache_misses"]
            rate = w["cache_hits"] / acc if acc else cache.hit_rate()
            parts.append(f"cache hit-rate {rate * 100:.1f}%")
            parts.append(f"streamed {_fmt_bytes(cache.bytes_streamed)}, "
                         f"avoided {_fmt_bytes(cache.bytes_avoided)}")
        return " | ".join(parts)

    # -- pull-based export -------------------------------------------------
    def registry(self, cache=None, store=None,
                 telemetry=None) -> MetricsRegistry:
        """Every serving counter/gauge/histogram — plus the decode-cache,
        weight-store, and telemetry phase metrics when given — registered
        by name in a pull-based :class:`MetricsRegistry`."""
        reg = MetricsRegistry()
        for field, help_ in (
                ("tokens_generated", "tokens produced (prefill + decode)"),
                ("requests_admitted", "requests admitted to a slot"),
                ("requests_completed", "requests retired"),
                ("prefills", "monolithic batch-1 prefills"),
                ("prefill_chunks", "chunked-prefill chunks"),
                ("prefill_chunk_tokens", "prompt tokens through chunks"),
                ("decode_steps", "batched decode steps"),
                ("slot_steps", "decode steps x active slots"),
                ("capacity_steps", "decode steps x total slots"),
                ("waves", "wave-mode admission rounds"),
                ("page_use_steps", "decode steps x pages in use"),
                ("page_capacity_steps", "decode steps x pool pages"),
                ("kv_gather_bytes", "decode-path KV gather/scatter bytes"),
                ("kv_gather_bytes_avoided",
                 "decode-path KV copies avoided (pallas_paged)"),
                ("kv_prefill_gather_bytes",
                 "prefill-path KV install-copy bytes"),
                ("kv_prefill_gather_bytes_avoided",
                 "prefill install copies avoided (mixed-step)"),
                ("kv_codec_bytes_fp",
                 "resident KV page bytes at fp (codec step sum)"),
                ("kv_codec_bytes_resident",
                 "resident KV page bytes compressed (codec step sum)"),
                ("kv_bytes_avoided",
                 "KV pool bytes the codec kept out of HBM"),
                ("kernel_qblock_rounded",
                 "mixed steps run with a gcd-rounded q_block"),
                ("prefix_hits",
                 "admissions that mapped a cached prefix"),
                ("prefix_tokens_reused",
                 "prompt tokens served from shared KV pages"),
                ("prefill_chunks_avoided",
                 "prefill chunks skipped via prefix sharing"),
                ("prefix_cow_copies",
                 "shared KV pages copied on write"),
                ("prefix_evictions",
                 "prefix-index entries evicted under pressure"),
                ("shared_page_steps",
                 "decode steps x shared pages (occupancy sum)"),
                ("spec_rounds",
                 "speculative verifications (round x slot pairs)"),
                ("spec_draft_tokens",
                 "draft tokens proposed for verification"),
                ("spec_accepted_tokens",
                 "draft tokens the verifier accepted"),
                ("spec_rejected_tokens",
                 "draft tokens rolled back after rejection")):
            reg.counter(f"{field}_total",
                        (lambda f=field: getattr(self, f)), help_)
        reg.counter("prefill_seconds_total", lambda: self.prefill_s,
                    "wall seconds spent in prefill")
        reg.counter("decode_seconds_total", lambda: self.decode_s,
                    "wall seconds spent in decode steps")
        reg.counter("decode_stall_seconds_total",
                    lambda: self.decode_stall_s,
                    "chunk seconds while decode work waited")
        reg.gauge("pages_in_use", lambda: self.pages_in_use,
                  "KV pages holding live request state (last step)")
        reg.gauge("pages_total", lambda: self.pages_total,
                  "KV page-pool size (last step)")
        reg.gauge("shared_pages", lambda: self.shared_pages,
                  "KV pages referenced by >1 owner (last step)")
        reg.gauge("kv_codec_error_bound", lambda: self.kv_codec_error_bound,
                  "worst elementwise KV reconstruction error bound")
        reg.gauge("kv_capacity_multiplier",
                  lambda: self.kv_capacity_multiplier(),
                  "effective KV capacity multiplier (fp/resident bytes)")
        reg.gauge("spec_acceptance_rate",
                  lambda: self.spec_acceptance_rate(),
                  "fraction of proposed draft tokens accepted")
        for name, hist, help_ in (
                ("ttft_seconds", self.ttft_hist, "time to first token"),
                ("tpot_seconds", self.tpot_hist, "time per output token"),
                ("e2e_seconds", self.e2e_hist, "request end-to-end latency"),
                ("prefill_chunk_seconds", self.chunk_hist,
                 "prefill chunk duration"),
                ("decode_step_seconds", self.step_hist,
                 "decode step duration")):
            reg.histogram(name, hist, help_)
        if cache is not None:
            for name, kind, getter, help_ in cache.prom_metrics():
                getattr(reg, kind)(f"cache_{name}", getter, help_)
        if store is not None:
            for name, kind, getter, help_ in store.prom_metrics():
                getattr(reg, kind)(f"store_{name}", getter, help_)
        if telemetry is not None:
            for phase in sorted(telemetry.phases):
                safe = phase.replace(".", "_").replace("-", "_")
                reg.histogram(f"phase_{safe}_seconds",
                              (lambda p=phase: telemetry.phases[p]),
                              f"wall seconds per {phase} phase")
        return reg

    def render_prom(self, cache=None, store=None, telemetry=None) -> str:
        """Prometheus text exposition of :meth:`registry`."""
        return self.registry(cache=cache, store=store,
                             telemetry=telemetry).render()
