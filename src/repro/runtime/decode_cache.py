"""Capacity-bounded cache of decoded weight tiles — the software analogue of
the paper's §IV hardware caching unit.

The hardware structure caches *decoded Huffman sequences* next to the
decoder so the hot, frequency-skewed majority of codes is never re-decoded;
here the unit of reuse is one decode tile (the (W, S) substream-parallel
block the Pallas kernels consume), keyed ``(model, layer, tile)``.  During
batched decoding every step touches every tile of every compressed layer,
so a capacity that covers the decoded working set turns all steps after the
first into pure cache hits — the measured hit rate is the direct software
counterpart of the paper's decode-cell utilisation.

Eviction is pluggable behind :class:`EvictionPolicy`:

  * ``lru``  — least-recently-used (recency only; the classic choice, but a
    cyclic scan one tile larger than capacity degrades it to 0% hits);
  * ``lfu``  — least-frequently-used (observed access counts, insertion-age
    tie-break);
  * ``freq`` — :class:`FrequencyWeightedPolicy`, the paper-motivated policy:
    victims are picked by observed accesses *plus* a static prior seeded
    from ``core.frequency`` occurrence counts (§III-A skew, Fig. 3).  Tiles
    dominated by hot sequences are pinned before they have any access
    history, so a one-off cold scan cannot flush the hot set the way it
    flushes LRU.

Accounting:
  * miss  -> ``bytes_streamed``  += compressed tile bytes (HBM words fetched
             and pushed through the decoder);
  * hit   -> ``bytes_avoided``   += the same compressed bytes (traffic +
             decode work the cache absorbed);
  * evictions are counted, and the resident decoded bytes are bounded by
    ``capacity_bytes`` under every policy.  Re-inserting an existing key
    replaces it exactly (old ``nbytes`` released before the new are
    charged), so ``resident_bytes`` always equals the sum over live
    entries — tests/test_runtime.py locks this down.

Knobs, in one place:

  =====================  ===================================================
  knob                   effect
  =====================  ===================================================
  ``capacity_bytes``     ``None`` = unbounded (everything cached after its
                         first decode); ``0`` = caching disabled, the
                         paper's no-cache baseline; otherwise a hard bound
                         on resident decoded bytes.  Values larger than
                         capacity are never cached at all.
  ``policy``             ``"lru"`` | ``"lfu"`` | ``"freq"`` or any
                         ``EvictionPolicy`` instance; ``None`` = LRU.
  ``FrequencyWeighted-``
  ``Policy(prior_-``     weight of the static §III-A occurrence prior
  ``weight=0.8, ...)``   relative to one fresh access.  < 1 keeps live
                         history dominant (a just-touched tile always
                         outranks an idle pinned one — pinning can never
                         starve the working set); >= 1 lets the prior
                         dominate, appropriate when access recency carries
                         no signal (pure cyclic scans; the example drives
                         this with ``prior_weight=4``).
  ``... half_life=64``   access-count decay, in policy events (inserts +
                         hits).  Small = closer to LRU (history fades
                         fast); large = closer to pure frequency ranking.
                         ``1e6``-scale values effectively freeze counts so
                         the static prior decides victims.
  =====================  ===================================================
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Hashable

TileKey = Hashable   # canonically (model_id, layer_name, tile_index)


@dataclasses.dataclass
class _Entry:
    value: Any
    nbytes: int
    streamed_bytes: int     # compressed bytes needed to rebuild this tile


# ---------------------------------------------------------------------------
# eviction policies
# ---------------------------------------------------------------------------

class EvictionPolicy:
    """Victim-selection strategy for :class:`DecodeTileCache`.

    The cache owns the entries and the byte accounting; the policy only
    tracks the metadata it needs to answer :meth:`victim`.  The cache calls
    ``on_insert`` / ``on_hit`` / ``on_remove`` for every entry it holds, so
    a policy's key set always mirrors the cache's.  ``seed`` feeds static
    frequency priors (``core.frequency`` occurrence counts); policies that
    do not use priors ignore it.
    """

    name = "base"

    def on_insert(self, key: TileKey, nbytes: int) -> None:
        raise NotImplementedError

    def on_hit(self, key: TileKey) -> None:
        raise NotImplementedError

    def on_remove(self, key: TileKey) -> None:
        raise NotImplementedError

    def victim(self) -> TileKey:
        """Key to evict next (only called while entries exist)."""
        raise NotImplementedError

    def seed(self, key: TileKey, weight: float) -> None:
        """Static frequency prior for ``key`` (may precede insertion)."""

    def order(self) -> list:
        """Keys in eviction order (victim first) — introspection only."""
        raise NotImplementedError

    def clear(self) -> None:
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    """Evict the least-recently-used entry."""

    name = "lru"

    def __init__(self):
        self._order: collections.OrderedDict[TileKey, None] = \
            collections.OrderedDict()

    def on_insert(self, key, nbytes):
        self._order[key] = None
        self._order.move_to_end(key)

    def on_hit(self, key):
        self._order.move_to_end(key)

    def on_remove(self, key):
        self._order.pop(key, None)

    def victim(self):
        return next(iter(self._order))

    def order(self):
        return list(self._order)

    def clear(self):
        self._order.clear()


class LFUPolicy(EvictionPolicy):
    """Evict the least-frequently-used entry (oldest breaks ties).

    Counts persist across evictions of the same key (classic LFU with
    perfect history): a tile that was hot, evicted, and re-decoded resumes
    its old count instead of restarting at the bottom of the pile.
    """

    name = "lfu"

    def __init__(self):
        self._count: collections.Counter = collections.Counter()
        self._tick = 0
        self._age: dict[TileKey, int] = {}

    def _score(self, key):
        return (self._count[key], self._age[key])

    def on_insert(self, key, nbytes):
        self._count[key] += 1
        self._tick += 1
        self._age[key] = self._tick

    def on_hit(self, key):
        self._count[key] += 1

    def on_remove(self, key):
        self._age.pop(key, None)

    def victim(self):
        return min(self._age, key=self._score)

    def order(self):
        return sorted(self._age, key=self._score)

    def clear(self):
        self._count.clear()
        self._age.clear()
        self._tick = 0


class FrequencyWeightedPolicy(EvictionPolicy):
    """Evict the entry with the lowest prior-seeded, aged frequency score.

    Score = exponentially aged access count + normalised static prior.
    The prior comes from ``core.frequency`` occurrence counts (how much of
    the paper's skewed sequence mass a tile carries) via :meth:`seed`; it
    ranks tiles before any access history exists and keeps hot tiles
    resident through access patterns that defeat recency (one-off scans,
    bursty cold tenants).  Observed counts decay with a half-life of
    ``half_life`` policy events, so a tenant that was hot long ago cannot
    starve the tiles a current burst is actively reusing — the aged count
    degrades gracefully to LRU-like behaviour on un-seeded keys while the
    prior keeps the statically hot set pinned.  ``prior_weight`` < 1 keeps
    the prior subordinate to live history: a tile with a fresh access
    always outranks an idle pinned one, so pinning can never starve the
    working set a current request is actively scanning.
    """

    name = "freq"

    def __init__(self, prior_weight: float = 0.8,
                 half_life: float = 64.0):
        self.prior_weight = prior_weight
        self.half_life = half_life
        self._prior: dict[TileKey, float] = {}
        self._prior_max = 0.0
        self._count: dict[TileKey, float] = {}
        self._touch: dict[TileKey, int] = {}   # tick of the last access
        self._tick = 0
        self._age: dict[TileKey, int] = {}     # resident keys -> insert tick

    def seed(self, key, weight):
        self._prior[key] = float(weight)
        self._prior_max = max(self._prior_max, float(weight))

    def _decayed(self, key) -> float:
        count = self._count.get(key, 0.0)
        if not count:
            return 0.0
        return count * 0.5 ** ((self._tick - self._touch[key])
                               / self.half_life)

    def _bump(self, key):
        self._tick += 1
        self._count[key] = self._decayed(key) + 1.0
        self._touch[key] = self._tick

    def _score(self, key):
        prior = self._prior.get(key, 0.0)
        norm = prior / self._prior_max if self._prior_max else 0.0
        return (self._decayed(key) + self.prior_weight * norm,
                self._age[key])

    def on_insert(self, key, nbytes):
        self._bump(key)
        self._age[key] = self._tick

    def on_hit(self, key):
        self._bump(key)

    def on_remove(self, key):
        self._age.pop(key, None)

    def victim(self):
        return min(self._age, key=self._score)

    def order(self):
        return sorted(self._age, key=self._score)

    def clear(self):
        self._count.clear()
        self._touch.clear()
        self._age.clear()
        self._tick = 0


POLICIES: dict[str, Callable[[], EvictionPolicy]] = {
    "lru": LRUPolicy,
    "lfu": LFUPolicy,
    "freq": FrequencyWeightedPolicy,
}


def make_policy(policy: str | EvictionPolicy | None) -> EvictionPolicy:
    """Policy instance from a name (``lru`` | ``lfu`` | ``freq``), an
    instance (passed through), or None (default LRU)."""
    if policy is None:
        return LRUPolicy()
    if isinstance(policy, EvictionPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {policy!r}; "
            f"expected one of {sorted(POLICIES)}") from None


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------

class DecodeTileCache:
    """Policy-driven cache of decoded tiles with hit/miss/bytes accounting.

    ``capacity_bytes=None`` means unbounded (serve everything from cache
    after first decode); ``0`` disables caching entirely (every access is a
    miss — the paper's no-cache baseline).
    """

    def __init__(self, capacity_bytes: int | None = None,
                 policy: str | EvictionPolicy | None = None):
        self.capacity_bytes = capacity_bytes
        self.policy = make_policy(policy)
        self._entries: dict[TileKey, _Entry] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_streamed = 0
        self.bytes_avoided = 0
        self.resident_bytes = 0

    # -- core --------------------------------------------------------------
    def get(self, key: TileKey):
        """Decoded tile or None; counts the access and notifies the policy."""
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        self.bytes_avoided += entry.streamed_bytes
        self.policy.on_hit(key)
        return entry.value

    def put(self, key: TileKey, value, *, nbytes: int | None = None,
            streamed_bytes: int = 0) -> None:
        """Insert a freshly decoded tile (the decode's stream traffic is
        charged here) and evict policy victims beyond capacity.

        Re-inserting an existing key *replaces* it: the old entry's bytes
        are released before the new are charged, so updates never inflate
        ``resident_bytes`` (regression-tested)."""
        nbytes = int(getattr(value, "nbytes", 0) if nbytes is None else nbytes)
        self.bytes_streamed += streamed_bytes
        old = self._entries.pop(key, None)
        if old is not None:
            self.resident_bytes -= old.nbytes
            self.policy.on_remove(key)
        if self.capacity_bytes is not None and nbytes > self.capacity_bytes:
            return                      # too large to ever cache
        self._entries[key] = _Entry(value, nbytes, streamed_bytes)
        self.resident_bytes += nbytes
        self.policy.on_insert(key, nbytes)
        if self.capacity_bytes is not None:
            while self.resident_bytes > self.capacity_bytes and self._entries:
                vk = self.policy.victim()
                self.resident_bytes -= self._entries.pop(vk).nbytes
                self.policy.on_remove(vk)
                self.evictions += 1

    def get_or_decode(self, key: TileKey, decode: Callable[[], Any], *,
                      nbytes: int | None = None, streamed_bytes: int = 0):
        """Fetch-through helper -> (value, was_hit).  ``nbytes`` overrides
        the decoded value's own size (for values without ``.nbytes``)."""
        value = self.get(key)
        if value is not None:
            return value, True
        value = decode()
        self.put(key, value, nbytes=nbytes, streamed_bytes=streamed_bytes)
        return value, False

    def seed_frequency(self, key: TileKey, weight: float) -> None:
        """Record a static frequency prior (``core.frequency`` occurrence
        mass) for ``key``; no-op under policies that ignore priors."""
        self.policy.seed(key, weight)

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: TileKey) -> bool:
        return key in self._entries

    def keys(self):
        """Keys in eviction order (next victim first)."""
        return self.policy.order()

    @property
    def accesses(self) -> int:
        return self.hits + self.misses

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "policy": self.policy.name,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate(),
            "bytes_streamed": self.bytes_streamed,
            "bytes_avoided": self.bytes_avoided,
            "resident_bytes": self.resident_bytes,
            "entries": len(self._entries),
        }

    def prom_metrics(self) -> list:
        """(name, kind, getter, help) rows for a pull-based metrics
        registry (``ServeMetrics.registry`` prefixes them ``cache_``)."""
        return [
            ("hits_total", "counter", lambda: self.hits,
             "decode-tile cache hits"),
            ("misses_total", "counter", lambda: self.misses,
             "decode-tile cache misses"),
            ("evictions_total", "counter", lambda: self.evictions,
             "decode-tile cache evictions"),
            ("bytes_streamed_total", "counter", lambda: self.bytes_streamed,
             "compressed bytes fetched and decoded on misses"),
            ("bytes_avoided_total", "counter", lambda: self.bytes_avoided,
             "compressed bytes the cache absorbed on hits"),
            ("resident_bytes", "gauge", lambda: self.resident_bytes,
             "decoded bytes currently resident"),
            ("entries", "gauge", lambda: len(self._entries),
             "decoded tiles currently resident"),
        ]

    def reset_counters(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.bytes_streamed = self.bytes_avoided = 0

    def clear(self) -> None:
        self._entries.clear()
        self.policy.clear()
        self.resident_bytes = 0
