"""Capacity-bounded cache of decoded weight tiles — the software analogue of
the paper's §IV hardware caching unit.

The hardware structure caches *decoded Huffman sequences* next to the
decoder so the hot, frequency-skewed majority of codes is never re-decoded;
here the unit of reuse is one decode tile (the (W, S) substream-parallel
block the Pallas kernels consume), keyed ``(model, layer, tile)``.  During
batched decoding every step touches every tile of every compressed layer,
so a capacity that covers the decoded working set turns all steps after the
first into pure cache hits — the measured hit rate is the direct software
counterpart of the paper's decode-cell utilisation.

Accounting:
  * miss  -> ``bytes_streamed``  += compressed tile bytes (HBM words fetched
             and pushed through the decoder);
  * hit   -> ``bytes_avoided``   += the same compressed bytes (traffic +
             decode work the cache absorbed);
  * evictions are counted, and the resident decoded bytes are bounded by
    ``capacity_bytes`` (LRU order, least-recently-used evicted first).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Hashable

TileKey = Hashable   # canonically (model_id, layer_name, tile_index)


@dataclasses.dataclass
class _Entry:
    value: Any
    nbytes: int
    streamed_bytes: int     # compressed bytes needed to rebuild this tile


class DecodeTileCache:
    """LRU cache of decoded tiles with hit/miss/bytes accounting.

    ``capacity_bytes=None`` means unbounded (serve everything from cache
    after first decode); ``0`` disables caching entirely (every access is a
    miss — the paper's no-cache baseline).
    """

    def __init__(self, capacity_bytes: int | None = None):
        self.capacity_bytes = capacity_bytes
        self._entries: collections.OrderedDict[TileKey, _Entry] = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_streamed = 0
        self.bytes_avoided = 0
        self.resident_bytes = 0

    # -- core --------------------------------------------------------------
    def get(self, key: TileKey):
        """Decoded tile or None; counts the access and refreshes LRU order."""
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        self.bytes_avoided += entry.streamed_bytes
        self._entries.move_to_end(key)
        return entry.value

    def put(self, key: TileKey, value, *, nbytes: int | None = None,
            streamed_bytes: int = 0) -> None:
        """Insert a freshly decoded tile (the decode's stream traffic is
        charged here) and evict LRU entries beyond capacity."""
        nbytes = int(getattr(value, "nbytes", 0) if nbytes is None else nbytes)
        self.bytes_streamed += streamed_bytes
        if key in self._entries:
            self.resident_bytes -= self._entries.pop(key).nbytes
        if self.capacity_bytes is not None and nbytes > self.capacity_bytes:
            return                      # too large to ever cache
        self._entries[key] = _Entry(value, nbytes, streamed_bytes)
        self.resident_bytes += nbytes
        if self.capacity_bytes is not None:
            while self.resident_bytes > self.capacity_bytes and self._entries:
                _, old = self._entries.popitem(last=False)
                self.resident_bytes -= old.nbytes
                self.evictions += 1

    def get_or_decode(self, key: TileKey, decode: Callable[[], Any], *,
                      streamed_bytes: int = 0):
        """Fetch-through helper -> (value, was_hit)."""
        value = self.get(key)
        if value is not None:
            return value, True
        value = decode()
        self.put(key, value, streamed_bytes=streamed_bytes)
        return value, False

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: TileKey) -> bool:
        return key in self._entries

    def keys(self):
        """Keys in LRU order (least recently used first)."""
        return list(self._entries.keys())

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate(),
            "bytes_streamed": self.bytes_streamed,
            "bytes_avoided": self.bytes_avoided,
            "resident_bytes": self.resident_bytes,
            "entries": len(self._entries),
        }

    def reset_counters(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.bytes_streamed = self.bytes_avoided = 0

    def clear(self) -> None:
        self._entries.clear()
        self.resident_bytes = 0
