"""Draft-token proposers for speculative decoding.

The scheduler asks a :class:`Drafter` for up to ``k`` guesses of each
slot's next tokens, stacks them after the slot's real next token as a
ragged ``q_lens[s] = 1 + k_s`` block, and lets the model score the whole
block in one step (``runtime.scheduler``).  Greedy verification accepts
the longest prefix of drafts that matches the model's own argmax chain,
so *any* proposal strategy — however wrong — leaves the output
token-identical to non-speculative decoding; drafters only trade
proposal cost against acceptance rate.

Two implementations:

* :class:`NGramDrafter` — no model at all: look the slot's recent
  suffix up in its own prompt + generation history and propose whatever
  followed it last time.  Free, and strong on the repetitive tails
  (code, templated text, looping structures) where speculation pays
  most.
* :class:`DraftModelDrafter` — a tiny stand-in transformer sharing the
  scheduler's :class:`~repro.runtime.weight_store.WeightStore` (its
  binarised MLP tiles live in the same decode-tile cache as the target
  model's, so the draft model rides the existing compression machinery
  instead of doubling resident weights).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config

_EMPTY = np.zeros((0,), np.int64)


class Drafter:
    """Interface: batched draft proposals.

    ``propose(histories, k, limits=None)`` takes one token history per
    decoding slot (prompt + everything generated so far, 1-D int arrays)
    and returns one int64 array of 0..k draft tokens per slot.  With
    ``limits``, proposal ``i`` is additionally capped at ``limits[i]``
    tokens (the scheduler passes each slot's remaining budget so a
    drafter can never push a slot past its ``max_new_tokens``).
    Proposals must be deterministic functions of the history — the
    token-identity oracle re-runs traces and expects identical blocks.
    """

    name = "drafter"

    def propose(self, histories, k: int, limits=None):
        raise NotImplementedError


def _clamp(draft: np.ndarray, k: int, limit) -> np.ndarray:
    n = min(len(draft), k if limit is None else min(k, max(0, int(limit))))
    return np.asarray(draft[:n], np.int64)


class NGramDrafter(Drafter):
    """Suffix-match drafting from the slot's own history.

    For each history, try n-gram orders ``max_order`` down to 1: find
    the most recent *earlier* occurrence of the history's final n-gram
    and propose the tokens that followed it.  Higher orders are tried
    first (more context, better acceptance); the first order with a
    match wins.  An empty history, or one whose suffix never occurred
    before, proposes nothing — speculation simply skips that slot for
    a step.
    """

    name = "ngram"

    def __init__(self, max_order: int = 3):
        assert max_order >= 1, max_order
        self.max_order = max_order

    def _propose_one(self, hist: np.ndarray, k: int) -> np.ndarray:
        n = len(hist)
        if n == 0 or k <= 0:
            return _EMPTY
        for order in range(min(self.max_order, n), 0, -1):
            suffix = hist[n - order:]
            # scan match starts right to left (most recent occurrence
            # first, excluding the suffix's own position) and take the
            # first match with a full k-token continuation; inside a
            # repeated run the most recent matches sit flush against the
            # history's end with only a truncated follow, so the longest
            # follow seen is kept as the fallback
            best = _EMPTY
            for start in range(n - order - 1, -1, -1):
                follow = hist[start + order:start + order + k]
                if np.array_equal(hist[start:start + order], suffix):
                    if len(follow) == k:
                        return np.asarray(follow, np.int64)
                    if len(follow) > len(best):
                        best = follow
            if len(best):
                return np.asarray(best, np.int64)
        return _EMPTY

    def propose(self, histories, k: int, limits=None):
        out = []
        for i, hist in enumerate(histories):
            h = np.asarray(hist, np.int64).reshape(-1)
            lim = None if limits is None else limits[i]
            out.append(_clamp(self._propose_one(h, k), k, lim))
        return out


# the tiny stand-in draft arch: minitron's block layout at toy width.
# ~100k params — one draft forward costs a fraction of a target
# mixed-step, which is the whole economic argument for draft models.
_DRAFT_SCALED = dict(num_layers=2, scan_repeats=2, d_model=64,
                     num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)


def draft_config(vocab_size: int, base: str = "minitron-8b"):
    """The draft model's config: ``base``'s architecture at toy scale,
    vocab-matched to the target (draft tokens index the target's
    logits rows, so the vocabularies must agree)."""
    return get_config(base).scaled(dtype="float32",
                                   vocab_size=vocab_size, **_DRAFT_SCALED)


class DraftModelDrafter(Drafter):
    """Greedy drafting with a tiny transformer on the shared weight store.

    The draft model's compressible weights are registered into the
    scheduler's :class:`WeightStore` under ``model_id="draft"`` and
    materialised through the same decode-tile cache as the target's
    (weights that cannot compress are kept raw).  Proposal is ``k``
    greedy forwards over a fixed ``window``-token suffix of the history
    — stateless full forwards at one compile shape, no KV cache to keep
    coherent with the scheduler's rollbacks.
    """

    name = "draft"

    def __init__(self, engine, *, base: str = "minitron-8b",
                 window: int = 32, seed: int = 0):
        from repro.models.api import get_model
        self.window = int(window)
        cfg = draft_config(engine.cfg.vocab_size, base)
        api = get_model(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(seed))
        self.store = engine.store
        self._raw = None
        try:
            self.store.register_model("draft", params)
            cfg = cfg.scaled(binarize_mlp=True)
        except ValueError:
            self._raw = params  # nothing compressible: serve raw
        self.cfg = cfg
        self._forward = jax.jit(
            lambda p, t: api.forward(cfg, p, t)[0])

    def _params(self):
        return self._raw if self._raw is not None \
            else self.store.materialize("draft")

    def propose(self, histories, k: int, limits=None):
        params = self._params()
        out = []
        for i, hist in enumerate(histories):
            h = list(np.asarray(hist, np.int64).reshape(-1))
            lim = None if limits is None else limits[i]
            kk = k if lim is None else min(k, max(0, int(lim)))
            if not h or kk <= 0:
                out.append(_EMPTY)
                continue
            draft = []
            for _ in range(kk):
                tail = h[-self.window:]
                toks = np.zeros((1, self.window), np.int32)
                toks[0, :len(tail)] = tail
                logits = self._forward(params, jnp.asarray(toks))
                nxt = int(jnp.argmax(logits[0, len(tail) - 1]))
                draft.append(nxt)
                h.append(nxt)
            out.append(np.asarray(draft, np.int64))
        return out


def make_drafter(spec: str, engine=None) -> Drafter | None:
    """Resolve a ``--speculate`` spec: ``"off"`` -> None, ``"ngram"`` ->
    :class:`NGramDrafter`, ``"draft"`` / ``"draft:<base-arch>"`` ->
    :class:`DraftModelDrafter` on ``engine``'s weight store."""
    if spec in (None, "off", ""):
        return None
    if spec == "ngram":
        return NGramDrafter()
    if spec == "draft" or spec.startswith("draft:"):
        if engine is None:
            raise ValueError("draft-model speculation needs an engine")
        base = spec.split(":", 1)[1] if ":" in spec else "minitron-8b"
        return DraftModelDrafter(engine, base=base)
    raise ValueError(f"unknown speculate spec {spec!r}; expected "
                     "'off', 'ngram', 'draft' or 'draft:<arch>'")
