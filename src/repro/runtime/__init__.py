"""Compressed-weight serving runtime.

Software analogue of the paper's evaluation hardware ("Exploiting Kernel
Compression on BNNs"), module by module:

  ===================  ====================================================
  module               paper structure it mirrors
  ===================  ====================================================
  weight_store         DRAM weight storage: compressed varlen Huffman
                       streams (§III layout); the fetch unit's re-blocking
                       into substream-parallel decode tiles happens lazily
                       on first use (stream -> tiled layout).  Async tile
                       prefetch dispatches the next layer's decodes while
                       the current layer reconstructs — the fetch unit
                       running ahead of the compute pipeline.
  decode_cache         §IV caching unit: a small capacity-bounded store of
                       *decoded* tiles beside the decoder.  Eviction is
                       pluggable (EvictionPolicy): LRU, LFU, and the
                       paper-motivated FrequencyWeighted policy whose
                       victims are ranked by observed accesses plus a
                       static prior seeded from core.frequency occurrence
                       counts — the paper's C1 observation (a few
                       sequences dominate a trained BNN's kernels) turned
                       into an eviction rule, so a one-off cold scan
                       cannot flush the hot set the way it flushes LRU.
  scheduler            the evaluation pipeline driver as slot-level
                       continuous batching: a SlotPool of fixed decode
                       slots, per-slot positions/KV lanes, exact-position
                       prefill on admission (monolithic batch-1 or
                       fixed-size chunks interleaved with decode under a
                       token budget), one decode step for all slots,
                       admit-on-retire.  KV lanes are optionally backed
                       by demand-allocated fixed-size pages
                       (PageAllocator + per-slot page tables) so short
                       requests stop paying long-request memory and the
                       pool grows without recompiling decode.  How decode
                       *reads* those pages is the attention-backend seam
                       (attn_backend): "gathered" copies each slot's
                       pages into a contiguous view per step (reference
                       oracle), "pallas_paged" hands the donated pools +
                       page tables to kernels.paged_attention, which
                       walks the table in-kernel — the §IV consume-in-
                       place principle applied to KV, zero per-step cache
                       copies.  With chunked prefill it runs mixed-step
                       execution: prefill chunks and decode tokens of
                       every slot ride one ragged batched invocation per
                       iteration, chunks write straight into the pools,
                       and the prefill path's install copy disappears
                       too.  mode="wave" reproduces the old
                       wave-granular scheduling as a slot config; every
                       scheduling config and both backends are
                       token-identical, only latency, occupancy, and
                       copy traffic differ.
  prefix_index         the paper's C1 skew applied to *requests*: a
                       page-granular token trie caching completed
                       prefills' KV pages, so a prompt extending a cached
                       prefix maps those refcounted pages into its page
                       table with zero prefill work; writes into shared
                       pages copy-on-write, and eviction ranks entries
                       with the same FrequencyWeighted prior (prefix
                       hits as occurrence mass) the decode cache uses.
  metrics              the paper's measured quantities as counters:
                       throughput, slot occupancy, decode-cache hit rate,
                       HBM bytes streamed vs avoided, prefill-chunk
                       latency / decode stall, KV-page occupancy, and
                       KV gather/scatter bytes moved vs avoided on both
                       the decode and prefill paths (the acceptance
                       signal for the in-kernel backend and the
                       mixed-step path: both must read 0 moved) — plus
                       latency *distributions*: log-bucket histograms
                       (TTFT / time-per-output-token / end-to-end /
                       chunk / step) with p50/p99, windowed stats-line
                       rates, and Prometheus text export (render_prom).
  telemetry            the observability layer: per-request lifecycle
                       span trees (queued -> admitted -> prefill_chunk[i]
                       -> decode -> retired) exportable as Chrome-trace
                       JSON / JSONL, phase-timing hooks (timed(phase)),
                       and the pull-based metrics registry behind
                       render_prom.  Default is a zero-cost null
                       recorder; telemetry never changes tokens.
  autotune             capacity recommendation: replay the materialize
                       access pattern over a capacity grid, find the
                       hit-rate-cliff knee (the launcher's
                       ``--cache-mb auto``); kernel launch-shape tuning:
                       time real paged-attention steps over a
                       (q_block, pages_per_step) grid on the live
                       model/page shapes, memoised per (arch, page, Q)
                       (the launcher's ``--kernel-tune auto``).
  ===================  ====================================================

The module <-> paper-structure mapping, with the request lifecycle
diagram, is documented in docs/ARCHITECTURE.md.

The fused Pallas path (``kernels.fused_decode_contraction``) remains the
in-kernel decoder (decode-on-the-fly, nothing cached); the runtime adds the
complementary cached mode and serves both from one WeightStore so they stay
bit-identical (tests/test_runtime.py round-trip).
"""

from repro.runtime.autotune import (find_knee, recommend_store_capacity,
                                    sweep_store, tune_kernel)
from repro.runtime.decode_cache import (DecodeTileCache, EvictionPolicy,
                                        FrequencyWeightedPolicy, LFUPolicy,
                                        LRUPolicy, make_policy)
from repro.runtime.metrics import ServeMetrics
from repro.runtime.prefix_index import PrefixIndex, PrefixNode
from repro.runtime.scheduler import (PageAllocator, Request, Scheduler,
                                     ServeEngine, Slot, SlotPool)
from repro.runtime.telemetry import (NULL_TELEMETRY, Histogram,
                                     MetricsRegistry, NullTelemetry,
                                     Telemetry, Tracer, parse_prom)
from repro.runtime.weight_store import StoredLayer, WeightStore

__all__ = [
    "DecodeTileCache",
    "EvictionPolicy",
    "FrequencyWeightedPolicy",
    "Histogram",
    "LFUPolicy",
    "LRUPolicy",
    "MetricsRegistry",
    "NULL_TELEMETRY",
    "NullTelemetry",
    "PageAllocator",
    "PrefixIndex",
    "PrefixNode",
    "Request",
    "Scheduler",
    "ServeEngine",
    "ServeMetrics",
    "Slot",
    "SlotPool",
    "StoredLayer",
    "Telemetry",
    "Tracer",
    "WeightStore",
    "find_knee",
    "make_policy",
    "parse_prom",
    "recommend_store_capacity",
    "sweep_store",
    "tune_kernel",
]
