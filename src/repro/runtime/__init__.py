"""Compressed-weight serving runtime.

Software analogue of the paper's evaluation hardware ("Exploiting Kernel
Compression on BNNs"), module by module:

  ===================  ====================================================
  module               paper structure it mirrors
  ===================  ====================================================
  weight_store         DRAM weight storage: compressed varlen Huffman
                       streams (§III layout); the fetch unit's re-blocking
                       into substream-parallel decode tiles happens lazily
                       on first use (stream -> tiled layout).
  decode_cache         §IV caching unit: a small capacity-bounded store of
                       *decoded* tiles beside the decoder.  The paper's C1
                       observation (a few sequences dominate a trained
                       BNN's kernels) is what makes a small cache effective
                       in hardware; at serving time the reuse axis is
                       temporal — every decode step re-reads every weight
                       tile, so cached tiles turn all steps after the first
                       into pure hits and the HBM stream traffic drops to
                       the compressed footprint once.
  scheduler            the evaluation pipeline driver: admits batched
                       requests, groups them into length buckets, prefills,
                       and interleaves decode steps (continuous batching);
                       ServeEngine is the seam later PRs plug into
                       (sharded stores, async prefetch, multi-backend).
  metrics              the paper's measured quantities as counters:
                       throughput, decode-cache hit rate, HBM bytes
                       streamed vs avoided.
  ===================  ====================================================

The fused Pallas path (``kernels.fused_decode_contraction``) remains the
in-kernel decoder (decode-on-the-fly, nothing cached); the runtime adds the
complementary cached mode and serves both from one WeightStore so they stay
bit-identical (tests/test_runtime.py round-trip).
"""

from repro.runtime.decode_cache import DecodeTileCache
from repro.runtime.metrics import ServeMetrics
from repro.runtime.scheduler import Request, Scheduler, ServeEngine
from repro.runtime.weight_store import StoredLayer, WeightStore

__all__ = [
    "DecodeTileCache",
    "Request",
    "Scheduler",
    "ServeEngine",
    "ServeMetrics",
    "StoredLayer",
    "WeightStore",
]
