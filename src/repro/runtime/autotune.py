"""Serving-time autotuning: decode-cache capacity and kernel launch shapes.

The paper's §IV working-set threshold reappears at serving time as a
cliff in the decode-cache hit-rate-vs-capacity curve: below the decoded
working set the cyclic materialize scan thrashes, at it the rate jumps
to ~(steps-1)/steps.  :func:`find_knee` locates that cliff on any
measured (capacity, hit-rate) curve and returns the knee — the smallest
capacity past the cliff within a tolerance of the best measured rate,
past which more memory buys no hits.  The benchmark's ``--autotune``
sweep and the launcher's ``--cache-mb auto`` both resolve through it.

:func:`recommend_store_capacity` runs the sweep against a *real*
registered model: it replays the materialize access pattern (every step
touches every tile of every compressed layer) through fresh
:class:`DecodeTileCache` instances at a grid of fractions of the
decoded working set — pure cache accounting, no tensor decodes, so the
sweep costs microseconds even for models whose real materialize takes
seconds.

:func:`tune_kernel` does the same for the paged attention kernel's
launch shape: it times real :func:`paged_mixed_attention` calls on a
synthetic hardware-tiled pool matching the live model's head layout and
page size, sweeping ``(q_block, pages_per_step)``, and memoises the
winner per ``(arch, page, Q)`` key so a fleet of pools resolves the
sweep once.
"""

from __future__ import annotations

import math
import time

from repro.runtime.decode_cache import DecodeTileCache

# the sweep grid: fine below 0.5 where the cliff usually sits
DEFAULT_FRACTIONS = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4,
                     0.5, 0.6, 0.75, 0.9, 1.0)


def find_knee(capacities, rates, tolerance: float = 0.02) -> int:
    """Index of the knee of a measured hit-rate-vs-capacity curve.

    The cliff is the largest hit-rate jump between consecutive
    capacities; the knee is the smallest capacity at/after the cliff
    whose hit rate is within ``tolerance`` of the best measured rate.
    Non-monotone curves where nothing past the cliff qualifies fall
    back to the best capacity itself, so the returned index always
    satisfies ``rates[i] >= max(rates) - tolerance``.

    Ties between equal-size jumps break toward the *latest* one: on a
    staircase curve (several equal jumps), the working-set cliff is the
    last riser — picking the first would return a capacity still inside
    the thrashing region.
    """
    if len(capacities) != len(rates) or not rates:
        raise ValueError("need equal-length, non-empty capacity/rate lists")
    best = max(rates)
    best_i = max(range(len(rates)), key=lambda i: rates[i])
    jumps = [rates[i] - rates[i - 1] for i in range(1, len(rates))]
    cliff = max(range(len(jumps)), key=lambda i: (jumps[i], i)) + 1 \
        if jumps else 0
    return next((i for i in range(cliff, len(rates))
                 if rates[i] >= best - tolerance), best_i)


def sweep_store(store, model_id: str, *, steps: int = 8,
                policy: str | None = None,
                fractions=DEFAULT_FRACTIONS) -> tuple:
    """Replay ``steps`` materialize scans of ``model_id`` at each cache
    capacity fraction -> (capacities, hit_rates).

    The scan is simulated through the cache's own accounting (every
    step touches every tile of every layer, in registration order, with
    the layer's real decoded/compressed byte sizes and frequency
    priors) — the access pattern is exact, only the tile *values* are
    stand-ins, so the hit rates match a real materialize sweep.
    """
    working_set = store.decoded_bytes(model_id)
    layers = [(name, layer, layer.ensure_tiled())
              for name, stack in store.layers(model_id).items()
              for layer in stack]
    # tiny models round int(working_set * frac) below a single decoded
    # tile (even to 0), making the low-fraction sweep points degenerate
    # caches that can never hold anything — clamp every capacity to the
    # largest decoded tile so each point can at least cache one tile
    min_cap = max((ts.c * ts.s * 4 for _, _, ts in layers), default=1)
    caps, rates = [], []
    for frac in fractions:
        cap = max(int(working_set * frac), min_cap)
        cache = DecodeTileCache(cap, policy=policy)
        for name, layer, ts in layers:
            if layer.tile_freq is not None:
                for t in range(ts.n_tiles):
                    cache.seed_frequency((model_id, layer.name, t),
                                         float(layer.tile_freq[t]))
        for _ in range(steps):
            for name, layer, ts in layers:
                nbytes = ts.c * ts.s * 4            # decoded int32 tile
                streamed = layer.tile_compressed_bytes()
                for t in range(ts.n_tiles):
                    cache.get_or_decode((model_id, layer.name, t),
                                        lambda: True, nbytes=nbytes,
                                        streamed_bytes=streamed)
        caps.append(cap)
        rates.append(cache.hit_rate())
    return caps, rates


def recommend_store_capacity(store, model_id: str, *, steps: int = 8,
                             policy: str | None = None,
                             fractions=DEFAULT_FRACTIONS,
                             tolerance: float = 0.02) -> dict:
    """Recommended decode-cache capacity for serving ``model_id``.

    Returns a dict: ``capacity`` (bytes, the knee), ``fraction`` (of
    the decoded working set), ``hit_rate`` (measured at the knee),
    ``best_rate``, ``working_set`` (decoded bytes), and the full
    ``capacities`` / ``rates`` sweep for reporting.
    """
    caps, rates = sweep_store(store, model_id, steps=steps, policy=policy,
                              fractions=fractions)
    knee = find_knee(caps, rates, tolerance=tolerance)
    return {
        "capacity": caps[knee],
        "fraction": fractions[knee],
        "hit_rate": rates[knee],
        "best_rate": max(rates),
        "working_set": store.decoded_bytes(model_id),
        "capacities": caps,
        "rates": rates,
    }


# memoised tune_kernel winners per (arch, page, Q, codec): a fleet of
# SlotPools (or repeated pool rebuilds on slot_len growth) resolves the
# sweep once per launch-shape point
_KERNEL_TUNE_CACHE: dict = {}

DEFAULT_PAGES_PER_STEP = (1, 2, 4)


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def tune_kernel(cfg, page_size: int, q: int, *, codec: bool = False,
                interpret: bool = False, n_slots: int = 4,
                pages_per_slot: int = 4,
                q_blocks=None, pages_per_step=DEFAULT_PAGES_PER_STEP,
                repeats: int = 3, seed: int = 0) -> dict:
    """Pick ``(q_block, pages_per_step)`` for the paged attention kernel
    on the live ``(arch, page, Q)`` point -> result dict.

    Builds a synthetic hardware-tiled page pool matching ``cfg``'s head
    layout (GQA: ``(KH, head_dim)`` pools; MLA: the shared latent /
    rope-part pools) at ``page_size``, then times one compiled
    ``paged_mixed_attention`` mixed step per candidate — real kernel,
    real shapes, stand-in values — and returns the fastest launch
    shape.  ``q_blocks`` defaults to the divisors of ``q`` (the kernel
    rounds non-divisors down to a gcd, so sweeping them would double
    count) and candidates are timed best-of-``repeats`` after a warmup
    call that eats the compile.

    Returns ``q_block`` / ``pages_per_step`` (the winner), ``best_ms``,
    the full ``timings`` list of ``(q_block, pages_per_step, ms)``,
    ``key`` — the ``(arch, page, Q, codec)`` memoisation key — and
    ``cached`` (True when a previous call already resolved this key).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import kv_codec
    from repro.kernels.paged_attention import paged_mixed_attention
    from repro.models.api import padded_page_dims

    key = (getattr(cfg, "name", cfg.family), int(page_size), int(q),
           bool(codec))
    hit = _KERNEL_TUNE_CACHE.get(key)
    if hit is not None:
        return {**hit, "cached": True}

    mla = bool(getattr(cfg, "kv_lora_rank", 0))
    h = cfg.num_heads
    kh, d = (1, cfg.kv_lora_rank) if mla else \
        (max(cfg.num_kv_heads, 1), cfg.head_dim)
    rows, (kh_p, d_p) = padded_page_dims((1, page_size, kh, d), 1,
                                         page_size, True)
    n_pages = n_slots * pages_per_slot + 1
    rng = np.random.default_rng(seed)
    pool = rng.normal(size=(n_pages, rows, kh_p, d_p)).astype(np.float32)
    table = rng.permutation(np.arange(1, n_pages))[
        :n_slots * pages_per_slot].reshape(n_slots, pages_per_slot)
    table = table.astype(np.int32)
    lengths = np.full((n_slots,), pages_per_slot * page_size, np.int32)
    q_lens = np.full((n_slots,), q, np.int32)
    qs = rng.normal(size=(n_slots, q, h, d)).astype(np.float32)
    kw = {}
    if codec:
        codes, scales = kv_codec.encode(jnp.asarray(pool), axes=(-2, -1))
        kw = dict(k_scales=scales, v_scales=scales,
                  codebook=kv_codec.codebook())
        pool = codes
    pool = jnp.asarray(pool)

    def run(qb, pps):
        out = paged_mixed_attention(
            qs, pool, pool, jnp.asarray(table), jnp.asarray(lengths),
            jnp.asarray(q_lens), page_size=page_size, q_block=qb,
            pages_per_step=pps, interpret=interpret, **kw)
        out.block_until_ready()

    timings = []
    for qb in (q_blocks if q_blocks is not None else _divisors(q)):
        for pps in pages_per_step:
            run(qb, pps)                       # warmup: compile
            best = math.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                run(qb, pps)
                best = min(best, time.perf_counter() - t0)
            timings.append((qb, pps, best * 1e3))
    qb, pps, ms = min(timings, key=lambda t: t[2])
    res = {"q_block": qb, "pages_per_step": pps, "best_ms": ms,
           "timings": timings, "key": key, "cached": False}
    _KERNEL_TUNE_CACHE[key] = res
    return res
