"""Multi-model registry of compressed binary weights.

Storage follows the paper's DRAM layout: each registered tensor is held as
one contiguous varlen Huffman *stream* (``core.compression`` stream layout —
the layout the compression-ratio tables measure).  The TPU-native *tiled*
layout (substream-parallel (W, S) blocks) is materialised lazily, per
layer, on first use — the runtime analogue of the paper's fetch unit
re-blocking DRAM words for the decoder.

Serving paths offered per registered layer:

  * :meth:`materialize` — rebuild the model's parameter pytree with every
    compressed tensor reconstructed as sign * per-channel-scale.  Tiles are
    fetched through the DecodeTileCache, so consecutive decode steps reuse
    decoded tiles instead of re-decoding (the acceptance metric of PR 1).
    The assembled device array is memoised and only rebuilt when at least
    one of its tiles missed the cache.
  * :meth:`fused_operands` — device operands (words, tables, meta) for the
    fused decode+GEMM Pallas path (``kernels.ops.compressed_binary_matmul``),
    built from the *same* cached tiles so both paths are bit-identical.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, compression, huffman
from repro.dist.sharding import path_name
from repro.kernels import ref
from repro.kernels.huffman_decode import pack_bitplane_tables
from repro.runtime.decode_cache import DecodeTileCache

# serving tiles reuse the offline layout default (C=8 -> 1024 sequences/
# tile); the tile is also the cache's eviction granularity
DEFAULT_CODES_PER_SUB = compression.DEFAULT_CODES_PER_SUB


def default_select(path: str, ndim: int) -> bool:
    """Default compression predicate: MLP projection matrices."""
    parts = path.split("/")
    return ndim >= 2 and parts[-1] in ("up", "gate", "down") \
        and "mlp" in parts[:-1]


@dataclasses.dataclass
class StoredLayer:
    """One compressed (N, K) binary tensor + its dequantisation scale."""

    name: str
    ct: compression.CompressedTensor      # stream layout (tiled=None)
    scale: np.ndarray                     # (N,) per-output-channel alpha
    n: int                                # output channels (rows of bits)
    k: int                                # true contraction length
    dtype: np.dtype
    # lazily materialised state
    tiled: compression.TiledStream | None = None
    tables: np.ndarray | None = None

    def ensure_tiled(self) -> compression.TiledStream:
        """First-use re-tiling: stream -> substream-parallel layout."""
        if self.tiled is None:
            seqs = huffman.decode_stream(
                self.ct.stream_words, self.ct.stream_bits, self.ct.assign,
                count=self.ct.n_seqs)
            self.tiled = compression.tile_stream(seqs, self.ct.assign)
            self.tables = self.ct.decode_tables()
        return self.tiled

    def tile_compressed_bytes(self) -> int:
        ts = self.ensure_tiled()
        return ts.w * ts.s * 4            # uint32 words per tile

    def stream_bytes(self) -> int:
        return int(self.ct.stream_words.size * 4)

    def packed_bytes(self) -> int:
        """9-bit channel-packed baseline footprint (paper's reference)."""
        return self.ct.n_seqs * huffman.SEQ_BITS // 8


@dataclasses.dataclass
class _ModelEntry:
    params: dict
    layers: dict[str, list[StoredLayer]]  # tree path -> per-repeat layers
    memo: dict = dataclasses.field(default_factory=dict)
    fused_memo: dict = dataclasses.field(default_factory=dict)


@functools.partial(jax.jit, static_argnames=("c",))
def _decode_tile_jit(words, tables, c):
    return ref.decode_tile(words, tables, c)


class WeightStore:
    """Registry: model id -> compressed layers, served through one cache."""

    def __init__(self, cache: DecodeTileCache | None = None):
        self.cache = cache if cache is not None else DecodeTileCache()
        self._models: dict[str, _ModelEntry] = {}

    # -- registration ------------------------------------------------------
    def register_model(self, model_id: str, params, *,
                       select: Callable[[str, int], bool] = default_select,
                       cluster: bool = False) -> dict:
        """Compress every selected weight of ``params`` into the store.

        Selected 2-d leaves (d_in, d_out) are binarised in the BNN layer
        convention (``layers.binary_linear``): bits of w.T with per-output
        -channel scale mean|w|.  3-d leaves are treated as scan-stacked
        (R, d_in, d_out) and registered per repeat so each repeat owns its
        tiles.  Returns a summary dict (layer count, byte footprints).
        """
        if model_id in self._models:
            raise ValueError(f"model {model_id!r} already registered")
        layers: dict[str, list[StoredLayer]] = {}

        def visit(path, leaf):
            name = path_name(path)
            if not select(name, getattr(leaf, "ndim", 0)):
                return leaf
            w = np.asarray(leaf)
            if w.ndim == 2:
                stack = w[None]
            elif w.ndim == 3:
                stack = w
            else:
                return leaf
            layers[name] = [
                self._compress_tensor(f"{name}[{r}]", stack[r],
                                      cluster=cluster)
                for r in range(stack.shape[0])]
            # the uncompressed original is NOT retained: only its
            # shape/dtype stub stays in the serving tree skeleton
            return jax.ShapeDtypeStruct(w.shape, w.dtype)

        skeleton = jax.tree_util.tree_map_with_path(visit, params)
        if not layers:
            raise ValueError("no weights matched the compression predicate")
        self._models[model_id] = _ModelEntry(params=skeleton, layers=layers)
        return self.report(model_id)

    def _compress_tensor(self, name: str, w2: np.ndarray, *,
                         cluster: bool) -> StoredLayer:
        wt = np.ascontiguousarray(w2.T)                # (N=d_out, K=d_in)
        scale = np.abs(wt).mean(axis=1)                # binarize_weights alpha
        bits = (wt >= 0).astype(np.uint8)
        ct = compression.compress_gemm(bits, cluster=cluster, tiled=False)
        return StoredLayer(name=name, ct=ct, scale=scale,
                           n=wt.shape[0], k=wt.shape[1], dtype=w2.dtype)

    # -- tile-level serving ------------------------------------------------
    def _fetch_tiles(self, model_id: str, layer: StoredLayer
                     ) -> tuple[list, bool]:
        """All decode tiles of one layer via the cache ->
        (tiles [(C, S) int32], any_tile_missed)."""
        ts = layer.ensure_tiled()
        comp_bytes = layer.tile_compressed_bytes()
        tiles = []
        any_miss = False
        for t in range(ts.n_tiles):
            key = (model_id, layer.name, t)
            tile, hit = self.cache.get_or_decode(
                key,
                lambda t=t: np.asarray(_decode_tile_jit(
                    jnp.asarray(ts.words[t]), jnp.asarray(layer.tables),
                    ts.c)),
                streamed_bytes=comp_bytes)
            any_miss |= not hit
            tiles.append(tile)
        return tiles, any_miss

    def _fetch_sequences(self, model_id: str, layer: StoredLayer
                         ) -> tuple[np.ndarray, bool]:
        """(flat (n_seqs,) int32 in original order, any_tile_missed)."""
        tiles, any_miss = self._fetch_tiles(model_id, layer)
        flat = np.stack(tiles).reshape(-1)[: layer.ct.n_seqs]
        return flat, any_miss

    def _to_weights(self, layer: StoredLayer, tiles: list) -> np.ndarray:
        """Cached tiles -> (d_in, d_out) real tensor sign * alpha."""
        seqs = np.stack(tiles).reshape(-1)[: layer.ct.n_seqs]
        bits = bitpack.sequences_to_gemm(
            seqs.astype(np.uint16).reshape(layer.ct.seq_shape), layer.k)
        w = (bits.astype(np.float32) * 2.0 - 1.0) * layer.scale[:, None]
        return w.T.astype(layer.dtype)

    # -- model-level serving ----------------------------------------------
    def materialize(self, model_id: str):
        """Serving params: compressed leaves rebuilt from cached tiles.

        Call once per decode step; after the first step every tile is a
        cache hit and the memoised device arrays are returned as-is (the
        hit path only touches the cache for accounting — no bit unpack,
        reconstruction, or host->device transfer is repeated).
        """
        entry = self._models[model_id]

        def rebuild(path, leaf):
            name = path_name(path)
            stack = entry.layers.get(name)
            if stack is None:
                return leaf
            fetched = [self._fetch_tiles(model_id, l) for l in stack]
            if all(not miss for _, miss in fetched) and name in entry.memo:
                return entry.memo[name]
            arrs = [self._to_weights(l, tiles)
                    for l, (tiles, _) in zip(stack, fetched)]
            out = jnp.asarray(arrs[0] if len(leaf.shape) == 2
                              else np.stack(arrs))
            entry.memo[name] = out
            return out

        return jax.tree_util.tree_map_with_path(rebuild, entry.params)

    def fused_operands(self, model_id: str, path: str, repeat: int = 0,
                       *, gather: str = "onehot", codes: int | None = None):
        """(words, tables, meta) for the fused decode+GEMM kernel, built
        from the same cache-served bits as :meth:`materialize`."""
        entry = self._models[model_id]
        layer = entry.layers[path][repeat]
        mkey = (path, repeat, gather, codes)
        seqs, miss = self._fetch_sequences(model_id, layer)
        if not miss and mkey in entry.fused_memo:
            return entry.fused_memo[mkey]
        bits = bitpack.sequences_to_gemm(
            seqs.astype(np.uint16).reshape(layer.ct.seq_shape), layer.k)
        fc = compression.compress_gemm_fused(
            bits, cluster=False,
            codes_per_sub=codes or DEFAULT_CODES_PER_SUB)
        tables = fc.ct.decode_tables()
        if gather == "bitplane":
            tables = pack_bitplane_tables(tables)
        ops = (jnp.asarray(fc.words), jnp.asarray(tables),
               dict(k_true=fc.k_true, n_true=fc.n_true,
                    codes=codes or DEFAULT_CODES_PER_SUB,
                    scale=jnp.asarray(layer.scale.astype(np.float32)),
                    ratio_stream=fc.ct.ratio_stream(),
                    ratio_tiled=fc.ratio_tiled()))
        entry.fused_memo[mkey] = ops
        return ops

    # -- introspection -----------------------------------------------------
    def models(self) -> list[str]:
        return list(self._models)

    def layers(self, model_id: str) -> dict[str, list[StoredLayer]]:
        return self._models[model_id].layers

    def n_tiles(self, model_id: str) -> int:
        return sum(l.ensure_tiled().n_tiles
                   for ls in self._models[model_id].layers.values()
                   for l in ls)

    def decoded_bytes(self, model_id: str) -> int:
        """Total decoded-tile bytes of the model (cache working set)."""
        total = 0
        for ls in self._models[model_id].layers.values():
            for l in ls:
                ts = l.ensure_tiled()
                total += ts.n_tiles * ts.c * ts.s * 4       # int32 tiles
        return total

    def report(self, model_id: str) -> dict:
        entry = self._models[model_id]
        ls = [l for stack in entry.layers.values() for l in stack]
        packed = sum(l.packed_bytes() for l in ls)
        stream = sum(l.stream_bytes() for l in ls)
        return {
            "layers": len(ls),
            "packed_bytes": packed,
            "stream_bytes": stream,
            "ratio_stream": packed / max(stream, 1),
        }
