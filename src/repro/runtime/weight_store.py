"""Multi-model registry of compressed binary weights.

Storage follows the paper's DRAM layout: each registered tensor is held as
one contiguous varlen Huffman *stream* (``core.compression`` stream layout —
the layout the compression-ratio tables measure).  The TPU-native *tiled*
layout (substream-parallel (W, S) blocks) is materialised lazily, per
layer, on first use — the runtime analogue of the paper's fetch unit
re-blocking DRAM words for the decoder.

Serving paths offered per registered layer:

  * :meth:`materialize` — rebuild the model's parameter pytree with every
    compressed tensor reconstructed as sign * per-channel-scale.  Tiles are
    fetched through the DecodeTileCache, so consecutive decode steps reuse
    decoded tiles instead of re-decoding (the acceptance metric of PR 1).
    The assembled device array is memoised and only rebuilt when at least
    one of its tiles missed the cache.
  * :meth:`fused_operands` — device operands (words, tables, meta) for the
    fused decode+GEMM Pallas path (``kernels.ops.compressed_binary_matmul``),
    built from the *same* cached tiles so both paths are bit-identical.

Two frequency-path features ride on the tile fetch:

  * **prior seeding** — at first tiling, each tile's share of the layer's
    sequence-occurrence mass (``core.frequency`` histogram, the paper's
    §III-A skew) is pushed into the decode cache via ``seed_frequency`` so
    the FrequencyWeighted eviction policy can rank tiles before any access
    history exists;
  * **async prefetch** — while one layer's tiles are being reconstructed on
    the host, the *next* layer's missing tiles are already dispatched to the
    device decoder (jax async dispatch), so the device decode of layer i+1
    overlaps the host bit-unpack of layer i (the runtime analogue of the
    paper's fetch unit running ahead of the compute pipeline).  Prefetch
    changes latency only — hit/miss accounting and the decoded bits are
    identical with it on or off.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, compression, frequency, huffman
from repro.dist.sharding import path_name
from repro.kernels import ref
from repro.kernels.huffman_decode import pack_bitplane_tables
from repro.runtime.decode_cache import DecodeTileCache
from repro.runtime.telemetry import NULL_TELEMETRY

# serving tiles reuse the offline layout default (C=8 -> 1024 sequences/
# tile); the tile is also the cache's eviction granularity
DEFAULT_CODES_PER_SUB = compression.DEFAULT_CODES_PER_SUB


def default_select(path: str, ndim: int) -> bool:
    """Default compression predicate: MLP projection matrices."""
    parts = path.split("/")
    return ndim >= 2 and parts[-1] in ("up", "gate", "down") \
        and "mlp" in parts[:-1]


@dataclasses.dataclass
class StoredLayer:
    """One compressed (N, K) binary tensor + its dequantisation scale."""

    name: str
    ct: compression.CompressedTensor      # stream layout (tiled=None)
    scale: np.ndarray                     # (N,) per-output-channel alpha
    n: int                                # output channels (rows of bits)
    k: int                                # true contraction length
    dtype: np.dtype
    # lazily materialised state
    tiled: compression.TiledStream | None = None
    tables: np.ndarray | None = None
    tile_freq: np.ndarray | None = None   # per-tile occurrence mass
    freq_seeded: bool = False

    def ensure_tiled(self) -> compression.TiledStream:
        """First-use re-tiling: stream -> substream-parallel layout."""
        if self.tiled is None:
            seqs = huffman.decode_stream(
                self.ct.stream_words, self.ct.stream_bits, self.ct.assign,
                count=self.ct.n_seqs)
            self.tiled = compression.tile_stream(seqs, self.ct.assign)
            self.tables = self.ct.decode_tables()
            # per-tile frequency mass: how much of the layer's skewed
            # sequence-occurrence histogram (paper §III-A) each decode tile
            # carries -> static prior for FrequencyWeighted eviction.  Tail
            # padding indexes a zero sentinel bin so pad slots add no mass
            # (index 0 is the all-(-1) sequence, typically the hottest bin).
            hist = np.append(frequency.sequence_histogram(seqs), 0)
            per_tile = self.tiled.c * self.tiled.s
            padded = np.full(self.tiled.n_tiles * per_tile,
                             hist.size - 1, np.int64)
            padded[: seqs.size] = seqs
            self.tile_freq = hist[padded.reshape(
                self.tiled.n_tiles, per_tile)].sum(axis=1)
        return self.tiled

    def tile_compressed_bytes(self) -> int:
        ts = self.ensure_tiled()
        return ts.w * ts.s * 4            # uint32 words per tile

    def stream_bytes(self) -> int:
        return int(self.ct.stream_words.size * 4)

    def packed_bytes(self) -> int:
        """9-bit channel-packed baseline footprint (paper's reference)."""
        return self.ct.n_seqs * huffman.SEQ_BITS // 8


@dataclasses.dataclass
class _ModelEntry:
    params: dict
    layers: dict[str, list[StoredLayer]]  # tree path -> per-repeat layers
    stacked: dict[str, bool]              # tree path -> 3-d scan-stacked leaf
    memo: dict = dataclasses.field(default_factory=dict)
    fused_memo: dict = dataclasses.field(default_factory=dict)


@functools.partial(jax.jit, static_argnames=("c",))
def _decode_tile_jit(words, tables, c):
    return ref.decode_tile(words, tables, c)


class WeightStore:
    """Registry: model id -> compressed layers, served through one cache.

    ``prefetch=True`` dispatches the next layer's missing tile decodes to
    the device while the current layer's tiles are reconstructed on the
    host (async tile prefetch; bit-identical results either way).
    """

    def __init__(self, cache: DecodeTileCache | None = None, *,
                 prefetch: bool = False, telemetry=None):
        self.cache = cache if cache is not None else DecodeTileCache()
        self.prefetch = prefetch
        self.prefetch_dispatched = 0
        self.prefetch_used = 0
        self.telemetry = telemetry if telemetry is not None \
            else NULL_TELEMETRY
        self._models: dict[str, _ModelEntry] = {}

    # -- registration ------------------------------------------------------
    def register_model(self, model_id: str, params, *,
                       select: Callable[[str, int], bool] = default_select,
                       cluster: bool = False) -> dict:
        """Compress every selected weight of ``params`` into the store.

        Selected 2-d leaves (d_in, d_out) are binarised in the BNN layer
        convention (``layers.binary_linear``): bits of w.T with per-output
        -channel scale mean|w|.  3-d leaves are treated as scan-stacked
        (R, d_in, d_out) and registered per repeat so each repeat owns its
        tiles.  Returns a summary dict (layer count, byte footprints).
        """
        if model_id in self._models:
            raise ValueError(f"model {model_id!r} already registered")
        layers: dict[str, list[StoredLayer]] = {}
        stacked: dict[str, bool] = {}

        def visit(path, leaf):
            name = path_name(path)
            if not select(name, getattr(leaf, "ndim", 0)):
                return leaf
            w = np.asarray(leaf)
            if w.ndim == 2:
                stack = w[None]
            elif w.ndim == 3:
                stack = w
            else:
                return leaf
            layers[name] = [
                self._compress_tensor(f"{name}[{r}]", stack[r],
                                      cluster=cluster)
                for r in range(stack.shape[0])]
            stacked[name] = w.ndim == 3
            # the uncompressed original is NOT retained: only its
            # shape/dtype stub stays in the serving tree skeleton
            return jax.ShapeDtypeStruct(w.shape, w.dtype)

        skeleton = jax.tree_util.tree_map_with_path(visit, params)
        if not layers:
            raise ValueError("no weights matched the compression predicate")
        self._models[model_id] = _ModelEntry(params=skeleton, layers=layers,
                                             stacked=stacked)
        return self.report(model_id)

    def _compress_tensor(self, name: str, w2: np.ndarray, *,
                         cluster: bool) -> StoredLayer:
        wt = np.ascontiguousarray(w2.T)                # (N=d_out, K=d_in)
        scale = np.abs(wt).mean(axis=1)                # binarize_weights alpha
        bits = (wt >= 0).astype(np.uint8)
        ct = compression.compress_gemm(bits, cluster=cluster, tiled=False)
        return StoredLayer(name=name, ct=ct, scale=scale,
                           n=wt.shape[0], k=wt.shape[1], dtype=w2.dtype)

    # -- tile-level serving ------------------------------------------------
    def _seed_layer(self, model_id: str, layer: StoredLayer) -> None:
        """Push the layer's per-tile occurrence mass into the cache policy
        (once) so FrequencyWeighted eviction can rank its tiles."""
        if layer.freq_seeded:
            return
        for t in range(layer.tiled.n_tiles):
            self.cache.seed_frequency((model_id, layer.name, t),
                                      float(layer.tile_freq[t]))
        layer.freq_seeded = True

    def _prefetch_layer(self, model_id: str, layer: StoredLayer,
                        pending: dict) -> None:
        """Dispatch device decodes for the layer's missing tiles without
        blocking (jax async dispatch); results land in ``pending``."""
        ts = layer.ensure_tiled()
        missing = [t for t in range(ts.n_tiles)
                   if (model_id, layer.name, t) not in self.cache
                   and (model_id, layer.name, t) not in pending]
        if not missing:
            return                      # steady state: stay off the device
        with self.telemetry.timed("weights.prefetch", layer=layer.name,
                                  tiles=len(missing)):
            tables = jnp.asarray(layer.tables)
            for t in missing:
                pending[(model_id, layer.name, t)] = _decode_tile_jit(
                    jnp.asarray(ts.words[t]), tables, ts.c)
                self.prefetch_dispatched += 1

    def _fetch_tiles(self, model_id: str, layer: StoredLayer,
                     pending: dict | None = None) -> tuple[list, bool]:
        """All decode tiles of one layer via the cache ->
        (tiles [(C, S) int32], any_tile_missed).

        A miss consumes the prefetched in-flight decode when one exists
        (same accounting as a direct decode: the stream bytes were spent)."""
        ts = layer.ensure_tiled()
        self._seed_layer(model_id, layer)
        comp_bytes = layer.tile_compressed_bytes()
        tiles = []
        any_miss = False
        for t in range(ts.n_tiles):
            key = (model_id, layer.name, t)
            tile = self.cache.get(key)
            if tile is None:
                fut = pending.pop(key, None) if pending else None
                if fut is not None:
                    self.prefetch_used += 1
                    tile = np.asarray(fut)
                else:
                    with self.telemetry.timed("weights.decode_tile"):
                        tile = np.asarray(_decode_tile_jit(
                            jnp.asarray(ts.words[t]),
                            jnp.asarray(layer.tables), ts.c))
                self.cache.put(key, tile, streamed_bytes=comp_bytes)
                any_miss = True
            tiles.append(tile)
        return tiles, any_miss

    def _fetch_sequences(self, model_id: str, layer: StoredLayer
                         ) -> tuple[np.ndarray, bool]:
        """(flat (n_seqs,) int32 in original order, any_tile_missed)."""
        tiles, any_miss = self._fetch_tiles(model_id, layer)
        flat = np.stack(tiles).reshape(-1)[: layer.ct.n_seqs]
        return flat, any_miss

    def _to_weights(self, layer: StoredLayer, tiles: list) -> np.ndarray:
        """Cached tiles -> (d_in, d_out) real tensor sign * alpha."""
        seqs = np.stack(tiles).reshape(-1)[: layer.ct.n_seqs]
        bits = bitpack.sequences_to_gemm(
            seqs.astype(np.uint16).reshape(layer.ct.seq_shape), layer.k)
        w = (bits.astype(np.float32) * 2.0 - 1.0) * layer.scale[:, None]
        return w.T.astype(layer.dtype)

    # -- model-level serving ----------------------------------------------
    def materialize(self, model_id: str):
        """Serving params: compressed leaves rebuilt from cached tiles.

        Call once per decode step; after the first step every tile is a
        cache hit and the memoised device arrays are returned as-is (the
        hit path only touches the cache for accounting — no bit unpack,
        reconstruction, or host->device transfer is repeated).

        Layers are processed in registration order; with ``prefetch`` on,
        layer i+1's missing tile decodes are dispatched right after layer
        i's tiles are fetched, so they run on-device while layer i's
        weights are reconstructed host-side.
        """
        entry = self._models[model_id]
        names = list(entry.layers)
        pending: dict = {}
        rebuilt: dict = {}
        with self.telemetry.timed("weights.materialize", model=model_id):
            for i, name in enumerate(names):
                stack = entry.layers[name]
                fetched = [self._fetch_tiles(model_id, l, pending)
                           for l in stack]
                if self.prefetch and i + 1 < len(names):
                    for nxt in entry.layers[names[i + 1]]:
                        self._prefetch_layer(model_id, nxt, pending)
                if all(not miss for _, miss in fetched) \
                        and name in entry.memo:
                    rebuilt[name] = entry.memo[name]
                    continue
                arrs = [self._to_weights(l, tiles)
                        for l, (tiles, _) in zip(stack, fetched)]
                out = jnp.asarray(np.stack(arrs) if entry.stacked[name]
                                  else arrs[0])
                entry.memo[name] = out
                rebuilt[name] = out

        def sub(path, leaf):
            return rebuilt.get(path_name(path), leaf)

        return jax.tree_util.tree_map_with_path(sub, entry.params)

    def fused_operands(self, model_id: str, path: str, repeat: int = 0,
                       *, gather: str = "onehot", codes: int | None = None):
        """(words, tables, meta) for the fused decode+GEMM kernel, built
        from the same cache-served bits as :meth:`materialize`."""
        entry = self._models[model_id]
        layer = entry.layers[path][repeat]
        mkey = (path, repeat, gather, codes)
        seqs, miss = self._fetch_sequences(model_id, layer)
        if not miss and mkey in entry.fused_memo:
            return entry.fused_memo[mkey]
        bits = bitpack.sequences_to_gemm(
            seqs.astype(np.uint16).reshape(layer.ct.seq_shape), layer.k)
        fc = compression.compress_gemm_fused(
            bits, cluster=False,
            codes_per_sub=codes or DEFAULT_CODES_PER_SUB)
        tables = fc.ct.decode_tables()
        if gather == "bitplane":
            tables = pack_bitplane_tables(tables)
        ops = (jnp.asarray(fc.words), jnp.asarray(tables),
               dict(k_true=fc.k_true, n_true=fc.n_true,
                    codes=codes or DEFAULT_CODES_PER_SUB,
                    scale=jnp.asarray(layer.scale.astype(np.float32)),
                    ratio_stream=fc.ct.ratio_stream(),
                    ratio_tiled=fc.ratio_tiled()))
        entry.fused_memo[mkey] = ops
        return ops

    # -- introspection -----------------------------------------------------
    def models(self) -> list[str]:
        return list(self._models)

    def layers(self, model_id: str) -> dict[str, list[StoredLayer]]:
        return self._models[model_id].layers

    def n_tiles(self, model_id: str) -> int:
        return sum(l.ensure_tiled().n_tiles
                   for ls in self._models[model_id].layers.values()
                   for l in ls)

    def decoded_bytes(self, model_id: str) -> int:
        """Total decoded-tile bytes of the model (cache working set)."""
        total = 0
        for ls in self._models[model_id].layers.values():
            for l in ls:
                ts = l.ensure_tiled()
                total += ts.n_tiles * ts.c * ts.s * 4       # int32 tiles
        return total

    def prom_metrics(self) -> list:
        """(name, kind, getter, help) rows for a pull-based metrics
        registry (``ServeMetrics.registry`` prefixes them ``store_``)."""
        return [
            ("prefetch_dispatched_total", "counter",
             lambda: self.prefetch_dispatched,
             "tile decodes dispatched ahead of use"),
            ("prefetch_used_total", "counter",
             lambda: self.prefetch_used,
             "prefetched tile decodes consumed by a miss"),
        ]

    def report(self, model_id: str) -> dict:
        entry = self._models[model_id]
        ls = [l for stack in entry.layers.values() for l in stack]
        packed = sum(l.packed_bytes() for l in ls)
        stream = sum(l.stream_bytes() for l in ls)
        return {
            "layers": len(ls),
            "packed_bytes": packed,
            "stream_bytes": stream,
            "ratio_stream": packed / max(stream, 1),
        }
