"""Batched request scheduler + serving engine.

Turns the single-shot serve loop into a continuous-batching engine:

  admit    — requests queue up (prompt + generation budget) and are grouped
             into *waves* of up to ``batch_size`` sharing a length bucket;
  pad      — prompts are left-padded to the bucket length so one compiled
             prefill/decode pair serves the whole bucket;
  prefill  — one batched prefill fills the wave's KV cache;
  decode   — interleaved decode steps run all wave slots in lockstep; a slot
             that exhausts its budget is masked out, and the wave retires
             when every slot is done.  New waves then reuse the *same*
             decoded weight tiles from the cache — hit rates carry across
             waves, which is exactly the cross-invocation reuse the paper's
             hardware cache provides.

Every decode step asks the WeightStore to materialise the serving params:
on step 1 the tiles stream+decode (cache misses); from step 2 on they are
served from the decode cache and the memoised device arrays are reused.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import get_model
from repro.runtime import weight_store as ws_mod
from repro.runtime.decode_cache import DecodeTileCache
from repro.runtime.metrics import ServeMetrics
from repro.runtime.weight_store import WeightStore

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (L,) int32 token ids
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class ServeEngine:
    """Model + compressed weight store + decode cache + metrics.

    ``compress=True`` binarises and Huffman-compresses the model's MLP
    projections into the store and serves in BNN-MLP mode
    (``cfg.binarize_mlp``); ``compress=False`` is the uncompressed baseline
    on the same scheduler.
    """

    def __init__(self, cfg, params, *, compress: bool = True,
                 cache_bytes: int | None = None, model_id: str = "lm",
                 cluster: bool = False,
                 select: Callable[[str, int], bool] = ws_mod.default_select):
        self.cache = DecodeTileCache(cache_bytes)
        self.store = WeightStore(self.cache)
        self.metrics = ServeMetrics()
        self.model_id = model_id
        self.compressed = False
        if compress:
            try:
                self.report = self.store.register_model(
                    model_id, params, cluster=cluster, select=select)
                self.compressed = True
                cfg = cfg.scaled(binarize_mlp=True)
            except ValueError:
                # arch without compressible MLPs (pure SSM etc.): serve raw
                self.report = None
        self.cfg = cfg
        self.api = get_model(cfg)
        # compressed serving keeps only the store's compressed streams +
        # memoised reconstructions; the originals are released
        self._raw_params = None if self.compressed else params
        self._decode_jit = jax.jit(
            lambda p, c, t, q: self.api.decode_step(self.cfg, p, c, t, q))

    def step_params(self):
        """Per-step serving params (tile-cache-served when compressed)."""
        if self.compressed:
            return self.store.materialize(self.model_id)
        return self._raw_params

    # stubbed multimodal frontends, matching the launcher conventions
    def extra_inputs(self, batch: int) -> tuple:
        cfg = self.cfg
        if cfg.family == "vlm":
            return (jnp.zeros((batch, cfg.num_vision_tokens, cfg.d_model),
                              cfg.jnp_dtype),)
        if cfg.family == "audio":
            return (jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                              cfg.jnp_dtype),)
        return ()

    def pos_offset(self, prompt_len: int) -> int:
        """Absolute position of the first generated token."""
        if self.cfg.family == "vlm":
            return prompt_len + self.cfg.num_vision_tokens
        return prompt_len

    def cache_len(self, prompt_len: int, gen: int) -> int:
        return self.pos_offset(prompt_len) + gen

    def prefill(self, params, tokens, cache, *extra):
        if self.cfg.family == "vlm":
            return self.api.prefill(self.cfg, params, tokens, cache,
                                    vision_embeds=extra[0])
        return self.api.prefill(self.cfg, params, tokens, cache, *extra)

    def decode_step(self, params, cache, tok, pos: int):
        return self._decode_jit(params, cache, tok, jnp.int32(pos))

    def stats_line(self) -> str:
        return self.metrics.stats_line(self.cache if self.compressed
                                       else None)


class Scheduler:
    """Admit -> bucket -> prefill -> interleaved decode, wave after wave."""

    def __init__(self, engine: ServeEngine, *, batch_size: int = 4,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 log_every: int = 0, emit: Callable[[str], None] = print):
        self.engine = engine
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.log_every = log_every
        self.emit = emit
        self._queue: list[Request] = []
        self._next_rid = 0

    # -- admission ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int32).ravel()
        if prompt.shape[0] > self.buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds the largest "
                f"length bucket ({self.buckets[-1]}); truncate the prompt "
                f"or configure larger buckets")
        req = Request(self._next_rid, prompt, int(max_new_tokens))
        self._next_rid += 1
        self._queue.append(req)
        return req

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _admit_wave(self) -> list[Request]:
        """Up to batch_size queued requests sharing the head's bucket."""
        head_bucket = self._bucket(self._queue[0].prompt_len)
        wave, rest = [], []
        for req in self._queue:
            if len(wave) < self.batch_size and \
                    self._bucket(req.prompt_len) == head_bucket:
                wave.append(req)
            else:
                rest.append(req)
        self._queue = rest
        return wave

    # -- serving -----------------------------------------------------------
    def run(self) -> list[Request]:
        """Serve the queue to completion -> completed requests."""
        completed: list[Request] = []
        while self._queue:
            completed.extend(self._run_wave(self._admit_wave()))
        return completed

    def _run_wave(self, wave: list[Request]) -> list[Request]:
        eng = self.engine
        m = eng.metrics
        bucket = self._bucket(max(r.prompt_len for r in wave))
        gen_budget = max(r.max_new_tokens for r in wave)
        b = len(wave)
        # Left-pad to the bucket length with token 0 so one compiled shape
        # serves the bucket.  Deliberate wave-granularity simplification:
        # pad tokens are visible to causal attention (no mask) and shift
        # RoPE positions, so a prompt shorter than its bucket is served as
        # if prefixed by pad tokens — exact per-request positions arrive
        # with slot-level continuous batching (ROADMAP runtime item).
        toks = np.zeros((b, bucket), np.int32)
        for i, r in enumerate(wave):
            toks[i, bucket - r.prompt_len:] = r.prompt

        t0 = time.monotonic()
        params = eng.step_params()
        cache = eng.api.init_cache(eng.cfg, b,
                                   eng.cache_len(bucket, gen_budget))
        logits, cache = eng.prefill(params, jnp.asarray(toks), cache,
                                    *eng.extra_inputs(b))
        jax.block_until_ready(logits)
        m.record_prefill(b, time.monotonic() - t0)

        offset = eng.pos_offset(bucket)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for step in range(gen_budget):
            t0 = time.monotonic()
            params = eng.step_params()
            active = 0
            for i, r in enumerate(wave):
                if not r.done:
                    r.generated.append(int(tok[i, 0]))
                    active += 1
                    if len(r.generated) >= r.max_new_tokens:
                        r.done = True
            logits, cache = eng.decode_step(params, cache, tok, offset + step)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                .astype(jnp.int32)
            jax.block_until_ready(tok)
            m.record_decode_step(active, time.monotonic() - t0)
            if self.log_every and m.decode_steps % self.log_every == 0:
                self.emit(eng.stats_line())
        if not bool(jnp.isfinite(logits[:, -1]).all()):
            raise RuntimeError(
                "non-finite logits in decode wave (compressed "
                "reconstruction or model numerics are broken)")
        m.record_completed(len(wave))
        return wave
