"""Slot-level continuous batching: SlotPool + scheduler + serving engine.

The serving core is a **SlotPool** — a fixed set of decode slots, each one
batch lane of a pooled per-slot KV cache.  Every per-request quantity the
old wave loop shared across a batch is per-slot state here:

  admit    — a queued request takes any free slot: its prompt is prefilled
             alone (batch-1, exact length, exact positions — no pad tokens
             visible to attention, no RoPE shift) and the filled cache is
             scattered into the slot's lane;
  decode   — ONE jit(vmap(decode_step)) advances every slot with its own
             position; slots at different depths of different requests
             share each step's weight-tile fetch, so decoded-tile reuse is
             continuous across request boundaries instead of resetting at
             wave boundaries;
  retire   — a slot whose request exhausted its budget frees immediately
             and is refilled from the queue *before the next decode step*
             (admit-on-retire), so finished requests never idle a lane.

``mode="wave"`` reproduces the old wave-granular scheduling as a slot
configuration: admission only happens when the pool has fully drained, so
slots retire in place and freed lanes idle until the wave ends.  Both
modes run the same per-slot decode, which is what makes them produce
token-identical results (the scheduler equivalence test) — scheduling
policy changes throughput, never content.

Every decode step asks the WeightStore to materialise the serving params:
on step 1 the tiles stream+decode (cache misses); from step 2 on they are
served from the decode cache and the memoised device arrays are reused.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import get_model
from repro.runtime import weight_store as ws_mod
from repro.runtime.decode_cache import DecodeTileCache, EvictionPolicy
from repro.runtime.metrics import ServeMetrics
from repro.runtime.weight_store import WeightStore

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)
SLOT_LEN_QUANTUM = 16      # slot cache lengths round up to this many tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (L,) int32 token ids
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class ServeEngine:
    """Model + compressed weight store + decode cache + metrics.

    ``compress=True`` binarises and Huffman-compresses the model's MLP
    projections into the store and serves in BNN-MLP mode
    (``cfg.binarize_mlp``); ``compress=False`` is the uncompressed baseline
    on the same scheduler.  ``cache_policy`` picks the decode-cache
    eviction policy (``lru`` | ``lfu`` | ``freq`` or an EvictionPolicy
    instance); ``prefetch`` toggles async next-layer tile prefetch.
    """

    def __init__(self, cfg, params, *, compress: bool = True,
                 cache_bytes: int | None = None, model_id: str = "lm",
                 cluster: bool = False,
                 cache_policy: str | EvictionPolicy | None = None,
                 prefetch: bool = True,
                 select: Callable[[str, int], bool] = ws_mod.default_select):
        self.cache = DecodeTileCache(cache_bytes, policy=cache_policy)
        self.store = WeightStore(self.cache, prefetch=prefetch)
        self.metrics = ServeMetrics()
        self.model_id = model_id
        self.compressed = False
        if compress:
            try:
                self.report = self.store.register_model(
                    model_id, params, cluster=cluster, select=select)
                self.compressed = True
                cfg = cfg.scaled(binarize_mlp=True)
            except ValueError:
                # arch without compressible MLPs (pure SSM etc.): serve raw
                self.report = None
        self.cfg = cfg
        self.api = get_model(cfg)
        # compressed serving keeps only the store's compressed streams +
        # memoised reconstructions; the originals are released
        self._raw_params = None if self.compressed else params
        # per-slot decode: vmap gives every batch lane its own position and
        # cache lane (leaves (S, 1, ...)); one compile per (S, slot_len).
        # The pooled cache is donated — the KV update happens in place
        # instead of copying every lane's cache each step.
        self._slot_decode_jit = jax.jit(
            jax.vmap(
                lambda p, c, t, q: self.api.decode_step(self.cfg, p, c,
                                                        t, q),
                in_axes=(None, 0, 0, 0)),
            donate_argnums=(1,))
        self._decode_jit = jax.jit(
            lambda p, c, t, q: self.api.decode_step(self.cfg, p, c, t, q))

    def step_params(self):
        """Per-step serving params (tile-cache-served when compressed)."""
        if self.compressed:
            return self.store.materialize(self.model_id)
        return self._raw_params

    # stubbed multimodal frontends, matching the launcher conventions
    def extra_inputs(self, batch: int) -> tuple:
        cfg = self.cfg
        if cfg.family == "vlm":
            return (jnp.zeros((batch, cfg.num_vision_tokens, cfg.d_model),
                              cfg.jnp_dtype),)
        if cfg.family == "audio":
            return (jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                              cfg.jnp_dtype),)
        return ()

    def pos_offset(self, prompt_len: int) -> int:
        """Absolute position of the first generated token."""
        if self.cfg.family == "vlm":
            return prompt_len + self.cfg.num_vision_tokens
        return prompt_len

    def cache_len(self, prompt_len: int, gen: int) -> int:
        return self.pos_offset(prompt_len) + gen

    def prefill(self, params, tokens, cache, *extra):
        if self.cfg.family == "vlm":
            return self.api.prefill(self.cfg, params, tokens, cache,
                                    vision_embeds=extra[0])
        return self.api.prefill(self.cfg, params, tokens, cache, *extra)

    def prefill_request(self, params, prompt: np.ndarray, slot_len: int):
        """Batch-1 exact-position prefill -> (first generated token, filled
        slot cache with leaves (1, ...))."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        cache = self.api.init_cache(self.cfg, 1, slot_len)
        logits, cache = self.prefill(params, toks, cache,
                                     *self.extra_inputs(1))
        if not bool(jnp.isfinite(logits[0, -1]).all()):
            raise RuntimeError(
                "non-finite prefill logits (compressed reconstruction or "
                "model numerics are broken)")
        return int(jnp.argmax(logits[0, -1])), cache

    def slot_decode(self, params, pooled_cache, toks, poss):
        """One decode step for every slot: toks (S, 1, 1) int32, poss (S,)
        int32 -> (logits (S, 1, 1, V), new pooled cache)."""
        return self._slot_decode_jit(params, pooled_cache, toks, poss)

    def decode_step(self, params, cache, tok, pos: int):
        """Single shared-position decode (legacy path; slot serving goes
        through :meth:`slot_decode`)."""
        return self._decode_jit(params, cache, tok, jnp.int32(pos))

    def stats_line(self) -> str:
        return self.metrics.stats_line(self.cache if self.compressed
                                       else None)


@dataclasses.dataclass
class Slot:
    """One decode lane: its request and per-slot decode state.

    ``tok`` is the most recently generated token (already appended to the
    request) and the next decode input; ``pos`` is its absolute position.
    """

    index: int
    req: Request | None = None
    pos: int = 0
    tok: int = 0


class SlotPool:
    """Fixed decode slots over one pooled per-slot KV cache.

    The pooled cache holds each slot's cache as batch lane ``index``
    (leaves ``(n_slots, 1, ...)``); admission scatters a freshly prefilled
    batch-1 cache into the lane, decode advances all lanes with per-slot
    positions via the engine's vmapped step.  Free lanes keep decoding
    (fixed shapes — same cost as the old full-wave step) but their output
    is discarded and their state never leaks: admission overwrites the
    whole lane.
    """

    def __init__(self, engine: ServeEngine, n_slots: int, slot_len: int):
        self.engine = engine
        self.n_slots = n_slots
        self.slot_len = slot_len
        self.slots = [Slot(i) for i in range(n_slots)]
        specs = engine.api.init_cache_specs(engine.cfg, 1, slot_len)
        self.cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros((n_slots, *s.shape), s.dtype), specs)
        self._scatter = jax.jit(
            lambda pool, new, i: jax.tree_util.tree_map(
                lambda p, n: p.at[i].set(n.astype(p.dtype)), pool, new),
            donate_argnums=(0,))

    def free(self) -> list[Slot]:
        return [s for s in self.slots if s.req is None]

    def active(self) -> list[Slot]:
        return [s for s in self.slots if s.req is not None]

    def admit(self, req: Request, params) -> tuple[Slot, int]:
        """Prefill ``req`` into a free slot -> (slot, first token)."""
        slot = self.free()[0]
        if self.engine.cache_len(req.prompt_len, req.max_new_tokens) \
                > self.slot_len:
            raise ValueError(
                f"request {req.rid} needs "
                f"{self.engine.cache_len(req.prompt_len, req.max_new_tokens)}"
                f" cache positions > slot_len {self.slot_len}")
        tok, cache1 = self.engine.prefill_request(params, req.prompt,
                                                  self.slot_len)
        self.cache = self._scatter(self.cache, cache1,
                                   jnp.int32(slot.index))
        slot.req = req
        slot.tok = tok
        slot.pos = self.engine.pos_offset(req.prompt_len)
        return slot, tok

    def retire(self, slot: Slot) -> None:
        slot.req = None

    def decode(self, params) -> list[tuple[Slot, int, bool]]:
        """One vmapped decode step -> per active slot (slot, next token,
        logits_finite); advances each active slot's (tok, pos)."""
        active = self.active()
        toks = np.zeros((self.n_slots, 1, 1), np.int32)
        poss = np.zeros(self.n_slots, np.int32)
        for s in active:
            toks[s.index, 0, 0] = s.tok
            poss[s.index] = s.pos
        logits, self.cache = self.engine.slot_decode(
            params, self.cache, jnp.asarray(toks), jnp.asarray(poss))
        last = logits[:, 0, -1]                           # (S, V)
        nxt = np.asarray(jnp.argmax(last, axis=-1)).astype(np.int32)
        finite = np.asarray(jnp.isfinite(last).all(axis=-1))
        out = []
        for s in active:
            s.pos += 1
            s.tok = int(nxt[s.index])
            out.append((s, s.tok, bool(finite[s.index])))
        return out


class Scheduler:
    """Admit -> per-slot prefill -> vmapped continuous decode.

    ``mode="continuous"`` (default): admit-on-retire — any freed slot is
    refilled from the queue before the next decode step.
    ``mode="wave"``: the old wave-granular scheduling as a slot config —
    admission waits until every slot has drained, and each admission round
    takes up to ``batch_size`` queued requests sharing the head request's
    length bucket (the old grouping).
    """

    def __init__(self, engine: ServeEngine, *, batch_size: int = 4,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 mode: str = "continuous", slot_len: int | None = None,
                 log_every: int = 0, emit: Callable[[str], None] = print):
        if mode not in ("continuous", "wave"):
            raise ValueError(f"unknown scheduling mode {mode!r}")
        self.engine = engine
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.mode = mode
        self.slot_len = slot_len
        self.log_every = log_every
        self.emit = emit
        self._queue: list[Request] = []
        self._pool: SlotPool | None = None
        self._next_rid = 0

    # -- admission ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int32).ravel()
        if prompt.shape[0] > self.buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds the largest "
                f"length bucket ({self.buckets[-1]}); truncate the prompt "
                f"or configure larger buckets")
        req = Request(self._next_rid, prompt, int(max_new_tokens))
        self._next_rid += 1
        self._queue.append(req)
        return req

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _wave_group(self) -> list[Request]:
        """Up to batch_size queued requests sharing the head's bucket."""
        head_bucket = self._bucket(self._queue[0].prompt_len)
        group, rest = [], []
        for req in self._queue:
            if len(group) < self.batch_size and \
                    self._bucket(req.prompt_len) == head_bucket:
                group.append(req)
            else:
                rest.append(req)
        self._queue = rest
        return group

    def _ensure_pool(self) -> SlotPool:
        """(Re)build the pool when the queue needs longer slot caches;
        reuse it otherwise so compiled decode shapes carry across runs."""
        eng = self.engine
        needed = max(eng.cache_len(r.prompt_len, r.max_new_tokens)
                     for r in self._queue)
        slot_len = self.slot_len or \
            -(-needed // SLOT_LEN_QUANTUM) * SLOT_LEN_QUANTUM
        if self._pool is None or self._pool.slot_len < slot_len or \
                self._pool.n_slots != self.batch_size:
            slot_len = max(slot_len, self._pool.slot_len if self._pool
                           else 0)
            self._pool = SlotPool(eng, self.batch_size, slot_len)
        return self._pool

    # -- serving -----------------------------------------------------------
    def run(self) -> list[Request]:
        """Serve the queue to completion -> completed requests."""
        if not self._queue:
            return []
        completed: list[Request] = []
        pool = self._ensure_pool()
        while self._queue or pool.active():
            self._admit(pool, completed)
            if pool.active():
                self._step(pool, completed)
        return completed

    def _admit(self, pool: SlotPool, completed: list[Request]) -> None:
        m = self.engine.metrics
        if self.mode == "wave":
            if pool.active() or not self._queue:
                return                    # wave mode: drain before admitting
            group = self._wave_group()[: pool.n_slots]
            m.record_wave()
        else:
            group = None                  # continuous: straight FIFO
        while self._queue or group:
            if group is not None:
                if not group:
                    return
                req = group.pop(0)
            else:
                if not pool.free():
                    return
                req = self._queue.pop(0)
            t0 = time.monotonic()
            params = self.engine.step_params()
            slot, tok = pool.admit(req, params)
            req.generated.append(tok)
            m.record_admit(1, time.monotonic() - t0, tokens=1)
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                pool.retire(slot)
                completed.append(req)
                m.record_completed(1)

    def _step(self, pool: SlotPool, completed: list[Request]) -> None:
        m = self.engine.metrics
        t0 = time.monotonic()
        params = self.engine.step_params()
        results = pool.decode(params)
        n_active = len(results)
        for slot, tok, finite in results:
            if not finite:
                raise RuntimeError(
                    f"non-finite logits in decode step for request "
                    f"{slot.req.rid} (compressed reconstruction or model "
                    f"numerics are broken)")
            req = slot.req
            req.generated.append(tok)
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                pool.retire(slot)         # admit-on-retire: lane refills
                completed.append(req)     # before the next decode step
                m.record_completed(1)
        m.record_decode_step(n_active, time.monotonic() - t0,
                             n_slots=pool.n_slots)
        if self.log_every and m.decode_steps % self.log_every == 0:
            self.emit(self.engine.stats_line())
