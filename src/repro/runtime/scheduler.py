"""Slot-level continuous batching: SlotPool + paged KV + chunked prefill.

The serving core is a **SlotPool** — a fixed set of decode slots, each one
batch lane of a pooled per-slot KV cache.  Every per-request quantity the
old wave loop shared across a batch is per-slot state here:

  admit    — a queued request takes any free slot: its prompt is prefilled
             alone (batch-1, exact length, exact positions — no pad tokens
             visible to attention, no RoPE shift) and the filled cache is
             scattered into the slot's lane.  With ``prefill_chunk`` set,
             the prompt is split into fixed-size chunks interleaved with
             decode steps of the other slots (a token budget per scheduler
             iteration bounds the decode-latency impact) — token-identical
             to monolithic prefill because each chunk attends to the
             already-prefilled cache under the same absolute-position
             masks;
  decode   — ONE jit(vmap(decode_step)) advances every slot with its own
             position; slots at different depths of different requests
             share each step's weight-tile fetch, so decoded-tile reuse is
             continuous across request boundaries instead of resetting at
             wave boundaries;
  retire   — a slot whose request exhausted its budget frees immediately
             and is refilled from the queue *before the next decode step*
             (admit-on-retire), so finished requests never idle a lane.

With ``kv_page_size`` set, the length-scaling KV lanes are backed by a
pool of fixed-size pages handed out by a :class:`PageAllocator` instead of
one monolithic ``(n_slots, 1, slot_len, ...)`` buffer: a slot owns only
the pages its positions have reached, short requests stop paying for
long-request memory, and the page pool can grow (``SlotPool.grow_pages``)
without recompiling the vmapped decode step — only the cheap page
gather/scatter re-traces.  ``kv_page_size=None`` keeps the PR-2 monolithic
lanes (donated in-place decode, zero gather traffic); one page = whole
lane reproduces the same tokens through the paged machinery (equivalence
locked down in tests/test_paged_prefill.py).

Scheduler-state invariants (enforced by construction, asserted in tests):

  * slot lifecycle   — FREE (req is None) -> PREFILLING (req set,
    ``prefilling``; under the gathered backend the chunk cursor advances
    on a standalone batch-1 cache outside the pool, under the
    ``pallas_paged`` **mixed-step** path chunks write straight into the
    slot's pages/lane and no standalone cache exists) -> ACTIVE (cache
    in the lane/pages, decode advances ``pos``) -> FREE (retire releases
    pages + reservations).  Admission overwrites the whole lane — and
    mixed-step prefill rewrites every position before the masks can
    expose it — so a free lane's stale state can never leak into a new
    request.
  * page ownership   — a physical page is referenced by at most one slot's
    table row; page 0 is the shared dummy sink that absorbs writes from
    free lanes (which keep decoding for fixed shapes, output discarded)
    and is never read as a valid position (attention masks by absolute
    position, and every position < a slot's cursor has a real page).
  * no mid-flight OOM — admission reserves every page the request can ever
    need (ceil(cache_len / page_size)); on-demand allocation during decode
    draws from that reservation, so it cannot fail; retire returns unused
    reservations.
  * ``mode="wave"``   — reproduces the old wave-granular scheduling as a
    slot configuration: admission only happens when the pool has fully
    drained, so slots retire in place and freed lanes idle until the wave
    ends.  Both modes run the same per-slot decode, which is what makes
    them token-identical (the scheduler equivalence test) — scheduling
    policy changes throughput, never content.

Every decode step asks the WeightStore to materialise the serving params:
on step 1 the tiles stream+decode (cache misses); from step 2 on they are
served from the decode cache and the memoised device arrays are reused.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import kv_codec as kv_codec_mod
from repro.kernels.kv_codec import KV_CODECS
from repro.kernels.paged_attention import effective_q_block
from repro.models.api import (ATTN_BACKENDS, cache_layout, get_model,
                              padded_page_dims, supports_chunked_prefill,
                              supports_paged_attention,
                              supports_prefix_share, supports_speculation)
from repro.runtime import weight_store as ws_mod
from repro.runtime.decode_cache import DecodeTileCache, EvictionPolicy
from repro.runtime.metrics import ServeMetrics
from repro.runtime.prefix_index import PrefixIndex
from repro.runtime.telemetry import (NULL_TELEMETRY, PID_REQUEST,
                                     Telemetry)
from repro.runtime.weight_store import WeightStore

DEFAULT_BUCKETS = (16, 32, 64, 128, 256, 512, 1024, 2048)
SLOT_LEN_QUANTUM = 16      # slot cache lengths round up to this many tokens
DUMMY_PAGE = 0             # physical page that absorbs idle-lane writes

# capability downgrades warn once per (arch family, capability) so a
# fleet of Scheduler instances does not spam, but the first silent
# downgrade is impossible (satellite of the mixed-step refactor)
_FALLBACK_WARNED: set = set()


def _warn_fallback(family: str, capability: str, message: str) -> None:
    key = (family, capability)
    if key in _FALLBACK_WARNED:
        return
    _FALLBACK_WARNED.add(key)
    warnings.warn(message, RuntimeWarning, stacklevel=3)


# the kernel rounds a q_block that does not divide this step's Q down to
# gcd(Q, q_block); every rounded step bumps kernel_qblock_rounded, the
# first one per (Q, q_block) also warns so the degraded launch shape is
# impossible to miss
_QBLOCK_WARNED: set = set()


def _warn_qblock_rounded(qn: int, q_block: int) -> None:
    key = (qn, q_block)
    if key in _QBLOCK_WARNED:
        return
    _QBLOCK_WARNED.add(key)
    warnings.warn(
        f"kernel q_block={q_block} does not divide this step's Q={qn}; "
        f"rounding down to gcd={effective_q_block(qn, q_block)} "
        "(counted in kernel_qblock_rounded)", RuntimeWarning,
        stacklevel=3)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (L,) int32 token ids
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0                 # monotonic submission time
    t_admit: float | None = None          # monotonic admission time
    t_first: float | None = None          # monotonic first-token time
    t_done: float | None = None           # monotonic retire time

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def first_token_latency(self) -> float | None:
        """Seconds from submission to the first generated token."""
        return None if self.t_first is None else self.t_first - self.t_submit


class PageAllocator:
    """Free-list allocator over a fixed set of physical KV page ids, with
    admission-time reservations.

    ``reserve(n)`` earmarks capacity without picking pages (called once per
    admitted request with its worst-case page count); ``alloc`` hands out a
    concrete page against an existing reservation, so on-demand allocation
    during decode can never fail mid-request.

    Pages are **refcounted** so prefix sharing can map one physical page
    into several owners: ``alloc`` starts a page at refcount 1, ``share``
    takes another reference (no free-list traffic, no reservation), and
    ``release`` drops one reference per call — the page returns to the
    free list only when the last reference goes.  Invariants (see
    tests/test_paged_prefill.py and tests/test_prefix_share.py): every id
    is free xor allocated-with-refcount >= 1, a page is never handed out
    twice without fully releasing it, releasing a page that is not
    allocated raises ``ValueError`` (double frees must never silently
    corrupt the free list), and ``reserved <= len(free)`` at all times.
    """

    def __init__(self, page_ids):
        ids = list(page_ids)
        self.total = len(ids)
        self._free = sorted(ids, reverse=True)    # pop() -> ascending ids
        self._allocated: set[int] = set()
        self._refs: dict[int, int] = {}
        self.reserved = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def available(self) -> int:
        """Pages free and not spoken for by a reservation."""
        return len(self._free) - self.reserved

    def reserve(self, n: int) -> bool:
        """Earmark ``n`` future allocations; False if they could not all be
        satisfied (the caller should defer admission, not retry-loop)."""
        if n > self.available():
            return False
        self.reserved += n
        return True

    def unreserve(self, n: int) -> None:
        assert 0 <= n <= self.reserved, (n, self.reserved)
        self.reserved -= n

    def alloc(self) -> int:
        """One page against an existing reservation (refcount 1)."""
        assert self.reserved > 0, "alloc without reservation"
        assert self._free, "reservation invariant broken: no free pages"
        self.reserved -= 1
        pid = self._free.pop()
        self._allocated.add(pid)
        self._refs[pid] = 1
        return pid

    def share(self, pid: int) -> int:
        """Take one more reference on an allocated page (prefix sharing).
        Consumes no free pages and no reservation."""
        if pid not in self._allocated:
            raise ValueError(f"share of unallocated page {pid}")
        self._refs[pid] += 1
        return pid

    def refcount(self, pid: int) -> int:
        return self._refs.get(pid, 0)

    def shared_pages(self) -> int:
        """Physical pages currently referenced by more than one owner."""
        return sum(1 for r in self._refs.values() if r >= 2)

    def release(self, page_ids) -> None:
        """Drop one reference per page; a page returns to the free list
        only when its last reference goes."""
        for pid in page_ids:
            if pid not in self._allocated:
                raise ValueError(f"double free of page {pid}")
            self._refs[pid] -= 1
            if self._refs[pid] == 0:
                del self._refs[pid]
                self._allocated.remove(pid)
                self._free.append(pid)

    def add_pages(self, page_ids) -> None:
        """Grow the pool (``SlotPool.grow_pages``)."""
        ids = list(page_ids)
        assert not (set(ids) & self._allocated) and \
            not (set(ids) & set(self._free))
        self.total += len(ids)
        self._free.extend(sorted(ids, reverse=True))


class ServeEngine:
    """Model + compressed weight store + decode cache + metrics.

    ``compress=True`` binarises and Huffman-compresses the model's MLP
    projections into the store and serves in BNN-MLP mode
    (``cfg.binarize_mlp``); ``compress=False`` is the uncompressed baseline
    on the same scheduler.  ``cache_policy`` picks the decode-cache
    eviction policy (``lru`` | ``lfu`` | ``freq`` or an EvictionPolicy
    instance); ``prefetch`` toggles async next-layer tile prefetch.
    ``telemetry`` accepts a ``runtime.telemetry.Telemetry`` recorder
    (request-lifecycle spans + phase histograms); the default is the
    zero-cost null recorder, and telemetry never changes generated
    tokens (tested).
    """

    def __init__(self, cfg, params, *, compress: bool = True,
                 cache_bytes: int | None = None, model_id: str = "lm",
                 cluster: bool = False,
                 cache_policy: str | EvictionPolicy | None = None,
                 prefetch: bool = True,
                 telemetry: Telemetry | None = None,
                 select: Callable[[str, int], bool] = ws_mod.default_select):
        self.cache = DecodeTileCache(cache_bytes, policy=cache_policy)
        self.telemetry = telemetry if telemetry is not None \
            else NULL_TELEMETRY
        self.store = WeightStore(self.cache, prefetch=prefetch,
                                 telemetry=self.telemetry)
        self.metrics = ServeMetrics()
        self.model_id = model_id
        self.compressed = False
        if compress:
            try:
                self.report = self.store.register_model(
                    model_id, params, cluster=cluster, select=select)
                self.compressed = True
                cfg = cfg.scaled(binarize_mlp=True)
            except ValueError:
                # arch without compressible MLPs (pure SSM etc.): serve raw
                self.report = None
        self.cfg = cfg
        self.api = get_model(cfg)
        # compressed serving keeps only the store's compressed streams +
        # memoised reconstructions; the originals are released
        self._raw_params = None if self.compressed else params
        # per-slot decode: vmap gives every batch lane its own position and
        # cache lane (leaves (S, 1, ...)); one compile per (S, slot_len).
        # The pooled cache is donated — the KV update happens in place
        # instead of copying every lane's cache each step.
        def _mk_slot_decode(kvq: bool):
            if kvq:
                step = lambda p, c, t, q: self.api.decode_step(
                    self.cfg, p, c, t, q, kv_quant=True)
            else:   # families without kv_quant (encdec) share this path
                step = lambda p, c, t, q: self.api.decode_step(
                    self.cfg, p, c, t, q)
            return jax.jit(jax.vmap(step, in_axes=(None, 0, 0, 0)),
                           donate_argnums=(1,))

        # keyed by kv_quant: under kv_codec="cluster" the gathered decode
        # quantises the new row before write *and* attention, matching
        # the paged kernel's in-VMEM decode numerics
        self._slot_decode_jits = {kvq: _mk_slot_decode(kvq)
                                  for kvq in (False, True)}
        self._slot_decode_jit = self._slot_decode_jits[False]
        self._decode_jit = jax.jit(
            lambda p, c, t, q: self.api.decode_step(self.cfg, p, c, t, q))
        # chunked prefill: batch-1, one compile per distinct chunk length
        # (fixed-size chunks + one remainder size keep that bounded);
        # keyed by kv_quant (the codec round-trip is baked into the trace)
        self._chunk_jit = None
        self._chunk_jits: dict = {}
        if self.api.prefill_chunk is not None:
            for kvq in (False, True):
                self._chunk_jits[kvq] = jax.jit(
                    functools.partial(
                        lambda kvq, p, c, t, q: self.api.prefill_chunk(
                            self.cfg, p, c, t, q, kv_quant=kvq), kvq),
                    donate_argnums=(1,))
            self._chunk_jit = self._chunk_jits[False]
        # speculative verification: vmapped over slot lanes (leaves
        # (S, 1, ...), toks (S, 1, Q), poss/q_lens (S,)), keyed by
        # (commit, kv_quant) — the non-committing scoring pass keeps the
        # input cache alive for the rollback-free commit pass, which
        # donates it
        self._verify_jits: dict = {}
        # pallas_paged backend: one compiled mixed step per (cache layout,
        # padded block width) — decode-only ticks compile at Q=1, chunked
        # ticks at Q=prefill_chunk (the pools are donated; the Pallas
        # kernel runs interpreted on hosts without a TPU, compiled on TPU)
        self.kernel_interpret = jax.default_backend() != "tpu"
        self._mixed_jits: dict = {}

    @property
    def supports_chunked_prefill(self) -> bool:
        return self._chunk_jit is not None and \
            supports_chunked_prefill(self.cfg)

    @property
    def supports_paged_attention(self) -> bool:
        return self.api.mixed_step is not None and \
            supports_paged_attention(self.cfg)

    def mixed_step(self, params, kcache, table, toks, poss, q_lens, *,
                   paged_flags: tuple, page_size: int, q_block: int = 0,
                   pages_per_step: int = 1, kv_scales=None):
        """One ragged mixed step for every slot straight over the paged
        pools: toks (S, Q) int32, poss (S,) int32 start positions, q_lens
        (S,) int32 real token counts (0 = free lane) -> (logits (S, Q, V),
        new cache tree).  ``kcache`` is donated — the page-pool update
        happens in place, with no gather/scatter anywhere on the prefill
        or decode path.

        ``q_block`` / ``pages_per_step`` are the tuned kernel launch
        parameters (``runtime.autotune.tune_kernel``); a ``q_block``
        that does not divide this step's ``Q`` silently rounds down to
        ``gcd(Q, q_block)`` inside the kernel, so the rounding is
        counted (``kernel_qblock_rounded``) and warned once here.

        ``kv_scales`` (``kv_codec="cluster"``): the scale-pool tree
        riding alongside int8 code pools; it is donated too and the
        return grows to ``(logits, new cache, new scales)``."""
        codec = kv_scales is not None
        qn = int(toks.shape[1])
        eff = effective_q_block(qn, q_block)
        if q_block and eff not in (q_block, qn):
            # eff == qn (e.g. decode's Q=1) still runs one whole-Q block
            # — nothing degraded; only a genuinely fragmented launch
            # counts
            self.metrics.record_kernel_qblock_rounded()
            _warn_qblock_rounded(qn, q_block)
        key = (paged_flags, page_size, qn, codec, q_block, pages_per_step)
        fn = self._mixed_jits.get(key)
        if fn is None:
            step = functools.partial(
                self.api.mixed_step, self.cfg,
                paged_flags=paged_flags, page_size=page_size,
                q_block=q_block, pages_per_step=pages_per_step,
                interpret=self.kernel_interpret)
            if codec:
                fn = jax.jit(
                    lambda p, c, t, tok, pos, ql, sc:
                        step(p, c, t, tok, pos, ql, scales=sc),
                    donate_argnums=(1, 6))
            else:
                fn = jax.jit(
                    lambda p, c, t, tok, pos, ql:
                        step(p, c, t, tok, pos, ql),
                    donate_argnums=(1,))
            self._mixed_jits[key] = fn
        if codec:
            return fn(params, kcache, table, toks, poss, q_lens, kv_scales)
        return fn(params, kcache, table, toks, poss, q_lens)

    def step_params(self):
        """Per-step serving params (tile-cache-served when compressed)."""
        if self.compressed:
            return self.store.materialize(self.model_id)
        return self._raw_params

    # stubbed multimodal frontends, matching the launcher conventions
    def extra_inputs(self, batch: int) -> tuple:
        cfg = self.cfg
        if cfg.family == "vlm":
            return (jnp.zeros((batch, cfg.num_vision_tokens, cfg.d_model),
                              cfg.jnp_dtype),)
        if cfg.family == "audio":
            return (jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                              cfg.jnp_dtype),)
        return ()

    def pos_offset(self, prompt_len: int) -> int:
        """Absolute position of the first generated token."""
        if self.cfg.family == "vlm":
            return prompt_len + self.cfg.num_vision_tokens
        return prompt_len

    def cache_len(self, prompt_len: int, gen: int) -> int:
        return self.pos_offset(prompt_len) + gen

    def prefill(self, params, tokens, cache, *extra):
        if self.cfg.family == "vlm":
            return self.api.prefill(self.cfg, params, tokens, cache,
                                    vision_embeds=extra[0])
        return self.api.prefill(self.cfg, params, tokens, cache, *extra)

    def prefill_request(self, params, prompt: np.ndarray, slot_len: int):
        """Batch-1 exact-position prefill -> (first generated token, filled
        slot cache with leaves (1, ...))."""
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        cache = self.api.init_cache(self.cfg, 1, slot_len)
        logits, cache = self.prefill(params, toks, cache,
                                     *self.extra_inputs(1))
        if not bool(jnp.isfinite(logits[0, -1]).all()):
            raise RuntimeError(
                "non-finite prefill logits (compressed reconstruction or "
                "model numerics are broken)")
        return int(jnp.argmax(logits[0, -1])), cache

    def fresh_slot_cache(self, slot_len: int):
        """Zeroed batch-1 cache for an in-flight chunked prefill."""
        return self.api.init_cache(self.cfg, 1, slot_len)

    def prefill_chunk_step(self, params, cache, chunk: np.ndarray,
                           pos: int, *, kv_quant: bool = False):
        """One prompt chunk at absolute positions pos..pos+len-1 ->
        (last-position logits, updated cache).  The cache argument is
        donated.  ``kv_quant`` round-trips the chunk's K/V through the
        cluster codec (gathered backend under ``kv_codec="cluster"``)."""
        toks = jnp.asarray(np.asarray(chunk, np.int32)[None])
        return self._chunk_jits[bool(kv_quant)](params, cache, toks,
                                                jnp.int32(pos))

    def verify_slots(self, params, pooled_cache, toks, poss, q_lens, *,
                     commit: bool, kv_quant: bool = False):
        """Speculative verification over slot lanes: toks (S, 1, Q) int32,
        poss (S,) int32 start positions, q_lens (S,) int32 real token
        counts (0 = idle lane, an exact cache no-op) -> (full logits
        (S, 1, Q, V), new pooled cache).

        ``commit=False`` scores drafts without donating the cache (the
        new cache is discarded, the input stays alive); ``commit=True``
        re-runs with the accepted lengths and donates, writing exactly
        the accepted tokens' KV in place — speculative rollback by
        construction, with no pool rewind."""
        key = (bool(commit), bool(kv_quant))
        fn = self._verify_jits.get(key)
        if fn is None:
            fn = jax.jit(
                functools.partial(
                    lambda kvq, p, c, t, pos, ql: jax.vmap(
                        lambda c1, t1, pos1, ql1: self.api.verify_step(
                            self.cfg, p, c1, t1, pos1, ql1, kv_quant=kvq),
                        in_axes=(0, 0, 0, 0))(c, t, pos, ql),
                    bool(kv_quant)),
                donate_argnums=(1,) if commit else ())
            self._verify_jits[key] = fn
        # q_lens rides as (S, 1) so each vmapped lane sees a (1,) array
        # (the ragged masks index it per-lane)
        return fn(params, pooled_cache, toks, poss,
                  jnp.asarray(q_lens, jnp.int32).reshape(-1, 1))

    def slot_decode(self, params, pooled_cache, toks, poss, *,
                    kv_quant: bool = False):
        """One decode step for every slot: toks (S, 1, 1) int32, poss (S,)
        int32 -> (logits (S, 1, 1, V), new pooled cache)."""
        return self._slot_decode_jits[bool(kv_quant)](
            params, pooled_cache, toks, poss)

    def decode_step(self, params, cache, tok, pos: int):
        """Single shared-position decode (legacy path; slot serving goes
        through :meth:`slot_decode`)."""
        return self._decode_jit(params, cache, tok, jnp.int32(pos))

    def stats_line(self) -> str:
        return self.metrics.stats_line(self.cache if self.compressed
                                       else None)

    def render_prom(self) -> str:
        """Prometheus text exposition of every serving metric: the
        ServeMetrics counters + histograms, the decode-cache and
        weight-store counters, and any telemetry phase histograms."""
        return self.metrics.render_prom(cache=self.cache, store=self.store,
                                        telemetry=self.telemetry)


@dataclasses.dataclass
class Slot:
    """One decode lane: its request and per-slot decode state.

    ``tok`` is the most recently generated token (already appended to the
    request) and the next decode input; ``pos`` is its absolute position.
    While ``prefilling``, the slot owns the request but not yet a lane:
    ``prefill_cursor`` counts prompt tokens already pushed through
    ``prefill_chunk`` into ``pcache`` (a standalone batch-1 cache that is
    installed into the pool when the last chunk lands).  ``reserved_left``
    is the slot's outstanding page reservation (paged pools only).
    ``prefix_matched`` counts prompt tokens served from the prefix index
    at admission — the chunk loop starts its cursor there, so those
    tokens cost zero prefill work; ``_prefix_nodes`` holds the mapped
    index nodes until the slot activates (gathered-backend pcache
    seeding).
    """

    index: int
    req: Request | None = None
    pos: int = 0
    tok: int = 0
    prefilling: bool = False
    prefill_cursor: int = 0
    pcache: object = None
    reserved_left: int = 0
    prefix_matched: int = 0
    _prefix_nodes: list | None = None


class SlotPool:
    """Fixed decode slots over one pooled per-slot KV cache.

    ``page_size=None`` (default): the PR-2 monolithic layout — each slot's
    cache is batch lane ``index`` of one pooled buffer (leaves
    ``(n_slots, 1, slot_len, ...)``), donated into the vmapped decode so
    the KV update happens in place.

    ``page_size=N``: length-scaling cache leaves are re-backed by a pool
    of fixed-size pages plus a per-slot page table.  How decode consumes
    that pool is the **attention-backend seam** (``backend``):

      * ``"gathered"`` — decode gathers each lane's pages into the same
        contiguous view the monolithic path uses (so the compiled decode
        step is identical) and scatters the updated pages back: two full
        cache copies per step, kept as the reference oracle;
      * ``"pallas_paged"`` — the pools are stored in the kernel-consumable
        layout (each pageable leaf's length axis becomes ``(n_pages,
        page)`` in place, the batch axis is dropped; lane leaves batch the
        slot axis in place of batch) and the donated tree is handed to
        ``mixed_step`` together with the page table: the Pallas
        kernel walks the table in-kernel and the per-step
        ``_gather``/``_scatter_pages`` copies disappear entirely.  The
        gather/scatter machinery survives only for admission (installing a
        prefilled batch-1 cache into the pool) and the fallback backend.

    Pages are allocated on demand as a slot's position crosses page
    boundaries and released at retire; leaves whose length does not scale
    with ``slot_len`` (rolling-window KV, recurrent states,
    cross-attention) stay per-slot lanes under both backends.  Page 0 is a
    shared dummy sink: unallocated table entries point at it, free lanes
    write into it, and attention's absolute-position masks guarantee it is
    never read as a valid key.

    ``page_capacity`` (default ``n_pages``) sizes the *physical buffers*;
    ``grow_pages`` up to the capacity is pure free-list bookkeeping — no
    buffer realloc, no re-trace, and (crucially, under ``pallas_paged``,
    whose compiled decode is keyed on the pool shape) no decode recompile.
    Growth beyond capacity reallocates with geometric headroom.

    Free lanes keep decoding (fixed shapes — same cost as the old
    full-wave step) but their output is discarded and their state never
    leaks: admission overwrites the whole lane.
    """

    def __init__(self, engine: ServeEngine, n_slots: int, slot_len: int,
                 *, page_size: int | None = None,
                 n_pages: int | None = None,
                 backend: str = "gathered",
                 page_capacity: int | None = None,
                 kv_codec: str = "none",
                 prefix_share: bool = False,
                 q_block: int = 0,
                 pages_per_step: int = 1,
                 hw_tiles: bool = False):
        if backend not in ATTN_BACKENDS:
            raise ValueError(f"unknown attention backend {backend!r}")
        if kv_codec not in KV_CODECS:
            raise ValueError(f"unknown kv codec {kv_codec!r}; "
                             f"choose from {KV_CODECS}")
        if (hw_tiles or pages_per_step != 1 or q_block) and \
                backend != "pallas_paged":
            raise ValueError("hw_tiles / pages_per_step / q_block shape "
                             "the pallas_paged kernel launch; the "
                             f"{backend!r} backend does not consume them")
        self.engine = engine
        self.n_slots = n_slots
        self.page_size = page_size
        self.paged = page_size is not None
        self.backend = backend
        self.kv_codec = kv_codec
        self.codec = kv_codec == "cluster"
        self.q_block = q_block
        self.pages_per_step = max(int(pages_per_step), 1)
        self.hw_tiles = hw_tiles
        self.prefix_share = prefix_share
        self.prefix: PrefixIndex | None = None
        if backend == "pallas_paged" and not self.paged:
            raise ValueError("the pallas_paged backend needs paged KV "
                             "lanes; set a page_size")
        if self.codec and not self.paged:
            raise ValueError("kv_codec='cluster' compresses the page "
                             "pools; set a kv page_size")
        if prefix_share and not self.paged:
            raise ValueError("prefix_share maps shared KV pages; set a "
                             "page_size")
        if self.paged:
            if page_size <= 0:
                raise ValueError(f"page_size must be positive: {page_size}")
            slot_len = -(-slot_len // page_size) * page_size
        self.slot_len = slot_len
        self.pages_per_slot = (slot_len // page_size) if self.paged else 0
        self.slots = [Slot(i) for i in range(n_slots)]
        self.kscales = None          # pallas_paged codec scale-pool tree
        self.page_scales = []        # gathered codec scale pools
        self.page_bytes_fp = 0
        self.page_bytes_resident = 0
        specs = engine.api.init_cache_specs(engine.cfg, 1, slot_len)
        # install() copies one freshly prefilled batch-1 cache into the
        # slot's pages + lane — the prefill-path gather traffic the
        # mixed-step path eliminates (its chunks write straight into the
        # pools, so a chunked pallas_paged admission never installs)
        self.install_bytes = sum(
            int(np.prod(s.shape)) * s.dtype.itemsize
            for s in jax.tree_util.tree_leaves(specs))
        if not self.paged:
            self.cache = jax.tree_util.tree_map(
                lambda s: jnp.zeros((n_slots, *s.shape), s.dtype), specs)
            self._scatter = jax.jit(
                lambda pool, new, i: jax.tree_util.tree_map(
                    lambda p, n: p.at[i].set(n.astype(p.dtype)), pool, new),
                donate_argnums=(0,))
            self.gather_bytes_per_step = 0
            self.gather_bytes_avoided_per_step = 0
            return
        # -- paged layout ---------------------------------------------------
        # A leaf is paged iff its shape scales 1:1 with slot_len (full-length
        # KV); rolling-window, recurrent-state, and encoder-length leaves
        # keep per-slot lanes.  ``models.api.cache_layout`` probes the spec
        # factory instead of guessing from shapes (scan-stacked leaves carry
        # a leading repeats dim, e.g. (R, 1, L, KH, HD)) — the same probe
        # the paged decode step interprets the tree with, so the scheduler
        # and the model cannot disagree about which leaves page.
        leaves_a, self._treedef = jax.tree_util.tree_flatten(specs)
        self._batch_axis, self._paged_axis = cache_layout(
            engine.api, engine.cfg, slot_len)
        self.paged_flags = tuple(ax is not None for ax in self._paged_axis)
        if n_pages is None:
            n_pages = n_slots * self.pages_per_slot + 1   # +1: dummy sink
        if n_pages < self.pages_per_slot + 1:
            raise ValueError(
                f"n_pages {n_pages} cannot back even one full slot "
                f"({self.pages_per_slot} pages + dummy)")
        self.n_pages = n_pages
        self.page_capacity = max(page_capacity or 0, n_pages)
        self.allocator = PageAllocator(range(1, n_pages))   # 0 = dummy
        self.table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        # per-step copy accounting: the gathered backend moves every paged
        # leaf's per-slot view twice per step (pool -> view, view -> pool);
        # the kernel backend moves none of it
        view_bytes = 2 * n_slots * sum(
            int(np.prod(sa.shape)) * sa.dtype.itemsize
            for sa, ax in zip(leaves_a, self._paged_axis) if ax is not None)
        cap = self.page_capacity
        # per-physical-page resident bytes across all paged leaves: fp at
        # rest vs kv_codec="cluster"'s int8 codes + one f32 scale per
        # (page, token) — the at-rest compression the codec-ratio metric
        # and benchmark section report
        fp_page, codec_page = 0, 0
        for sa, ax in zip(leaves_a, self._paged_axis):
            if ax is None:
                continue
            elems = int(np.prod(sa.shape)) // sa.shape[ax] * page_size
            feat = int(np.prod(sa.shape[ax + 1:])) or 1
            fp_page += elems * sa.dtype.itemsize
            codec_page += elems + (elems // feat) * 4
        self.page_bytes_fp = fp_page
        self.page_bytes_resident = codec_page if self.codec else fp_page
        if prefix_share:
            # every cache leaf must page for a mapped prefix to carry the
            # request's whole state (Scheduler gates on the
            # supports_prefix_share probe before building the pool)
            if not all(self.paged_flags):
                raise ValueError(
                    "prefix_share needs every cache leaf paged; this "
                    "arch keeps per-slot lanes a shared page cannot "
                    "carry")
            self.prefix = PrefixIndex(self.allocator, page_size,
                                      page_bytes=self.page_bytes_resident)
        if backend == "pallas_paged":
            self.gather_bytes_per_step = 0
            self.gather_bytes_avoided_per_step = view_bytes
            # kernel-consumable layout: length axis -> (n_pages, page) in
            # place with the batch-1 axis dropped; lane leaves carry the
            # slot axis where batch sat, so the paged decode runs all
            # slots in one batched trace
            # hardware tiling pads each pool's page (sublane) dim and
            # trailing feature (lane) dim toward the (8, 128) register
            # tiles; the padding is layout-only — write() zero-fills it,
            # the kernel masks the extra rows, and zero feature columns
            # drop out of every dot product exactly
            self.page_rows = padded_page_dims(
                (page_size,), 0, page_size, hw_tiles)[0] \
                if self.paged else page_size
            kleaves, sleaves = [], []
            # lane leaves under this backend are rolling-window KV: the
            # slot axis sits where batch sat (bax) and the W rolling rows
            # right behind it.  Speculative verification snapshots the
            # draft-covered rows before a mixed step and restores the
            # rejected ones after — a stale rejected row at position p
            # would otherwise be reinterpreted as position p - W inside
            # a future window.  ``lane_min_rows`` bounds the draft depth
            # (distinct modular rows per leaf).
            self._lane_info: list[tuple[int, int, int]] = []
            for li, (sa, ax, bax) in enumerate(zip(leaves_a,
                                                   self._paged_axis,
                                                   self._batch_axis)):
                if ax is not None:
                    assert bax == ax - 1 and sa.shape[bax] == 1, \
                        (sa.shape, ax, bax)
                    rows, feat = padded_page_dims(sa.shape, ax, page_size,
                                                  hw_tiles)
                    kleaves.append(jnp.zeros(
                        (*sa.shape[:ax - 1], cap, rows, *feat),
                        jnp.int8 if self.codec else sa.dtype))
                    sleaves.append(jnp.zeros(
                        (*sa.shape[:ax - 1], cap, rows), jnp.float32)
                        if self.codec else None)
                else:
                    kleaves.append(jnp.zeros(
                        (*sa.shape[:bax], n_slots, *sa.shape[bax + 1:]),
                        sa.dtype))
                    sleaves.append(None)
                    self._lane_info.append((li, bax, sa.shape[bax + 1]))
            self.lane_min_rows = min(
                (w for _, _, w in self._lane_info), default=None)
            self.kcache = jax.tree_util.tree_unflatten(self._treedef,
                                                       kleaves)
            # scale-pool tree: same treedef position-for-position, f32
            # (n_pages, page) pools at pageable leaves, None elsewhere —
            # the canonical per-leaf form mixed_step round-trips
            self.kscales = jax.tree_util.tree_unflatten(
                self._treedef, sleaves) if self.codec else None
            self._build_kernel_jits()
            return
        self.gather_bytes_per_step = view_bytes
        self.gather_bytes_avoided_per_step = 0
        self.pages = [
            jnp.zeros((cap, *sa.shape[:ax], page_size,
                       *sa.shape[ax + 1:]),
                      jnp.int8 if self.codec else sa.dtype)
            for sa, ax in zip(leaves_a, self._paged_axis) if ax is not None]
        # one f32 scale per (page, token) rides each code pool; gather
        # decodes pages back to fp views (the compiled decode step is
        # untouched), scatter re-encodes them — idempotently, so
        # untouched pages round-trip bit-identically
        self.page_scales = [
            jnp.zeros((cap, *sa.shape[:ax], page_size), jnp.float32)
            for sa, ax in zip(leaves_a, self._paged_axis)
            if ax is not None] if self.codec else []
        self.unpaged = [
            jnp.zeros((n_slots, *sa.shape), sa.dtype)
            for sa, ax in zip(leaves_a, self._paged_axis) if ax is None]
        self._build_page_jits()

    def _build_page_jits(self) -> None:
        axes = self._paged_axis
        pps, page, view = self.pages_per_slot, self.page_size, self.slot_len
        codec = self.codec
        dtypes = [sa.dtype for sa in
                  jax.tree_util.tree_flatten(
                      self.engine.api.init_cache_specs(
                          self.engine.cfg, 1, self.slot_len))[0]]

        def feat_axes(v_ndim, rest_ndim):
            # the trailing ``rest`` dims are the token's feature block,
            # reduced into one codec scale per (page, token)
            return tuple(range(v_ndim - rest_ndim, v_ndim))

        # A paged pool leaf is (n_pages, *lead, page, *rest) where the lane
        # leaf is (*lead, view, *rest) with view at axis ``ax``
        # (lead = leaf.shape[:ax]).  Gather pulls P pages per slot and
        # splices the page axis back into position ax; scatter inverts it.
        # Under kv_codec="cluster" the pools hold int8 codes + f32 scales:
        # gather decodes pages into the original-dtype views (so the
        # compiled decode step never changes), scatter re-encodes them.
        def gather(pages, scales, unpaged, table):
            views, pi, ui = [], 0, 0
            for ax, dt in zip(axes, dtypes):
                if ax is not None:
                    v = pages[pi][table]        # (S, P, *lead, page, *rest)
                    if codec:
                        sc = scales[pi][table]  # (S, P, *lead, page)
                        rest = v.ndim - sc.ndim
                        v = kv_codec_mod.decode(
                            v, sc.reshape(*sc.shape, *(1,) * rest)) \
                            .astype(dt)
                    pi += 1
                    v = jnp.moveaxis(v, 1, 1 + ax)   # (S, *lead, P, page, ..)
                    views.append(v.reshape(*v.shape[:1 + ax], view,
                                           *v.shape[3 + ax:]))
                else:
                    views.append(unpaged[ui])
                    ui += 1
            return jax.tree_util.tree_unflatten(self._treedef, views)

        def scatter(pages, scales, new_tree, table):
            leaves = jax.tree_util.tree_flatten(new_tree)[0]
            out_pages, out_scales, out_unpaged, pi = [], [], [], 0
            for leaf, ax in zip(leaves, axes):
                if ax is not None:
                    pool = pages[pi]
                    v = leaf.reshape(*leaf.shape[:1 + ax], pps, page,
                                     *leaf.shape[2 + ax:])
                    v = jnp.moveaxis(v, 1 + ax, 1)  # (S, P, *lead, page, ..)
                    if codec:
                        v, sc = kv_codec_mod.encode(
                            v, feat_axes(v.ndim, leaf.ndim - ax - 2))
                        out_scales.append(
                            scales[pi].at[table].set(sc))
                    pi += 1
                    out_pages.append(pool.at[table].set(v.astype(pool.dtype)))
                else:
                    out_unpaged.append(leaf)
            return out_pages, out_scales, out_unpaged

        def lane_scatter(pages, scales, unpaged, lane, row, i):
            leaves = jax.tree_util.tree_flatten(lane)[0]
            out_pages, out_scales, out_unpaged, pi, ui = [], [], [], 0, 0
            for leaf, ax in zip(leaves, axes):
                if ax is not None:
                    pool = pages[pi]
                    v = leaf.reshape(*leaf.shape[:ax], pps, page,
                                     *leaf.shape[1 + ax:])
                    v = jnp.moveaxis(v, ax, 0)  # (P, *lead, page, *rest)
                    if codec:
                        v, sc = kv_codec_mod.encode(
                            v, feat_axes(v.ndim, leaf.ndim - ax - 1))
                        out_scales.append(scales[pi].at[row].set(sc))
                    pi += 1
                    out_pages.append(pool.at[row].set(v.astype(pool.dtype)))
                else:
                    pool = unpaged[ui]
                    ui += 1
                    out_unpaged.append(pool.at[i].set(leaf.astype(pool.dtype)))
            return out_pages, out_scales, out_unpaged

        def page_copy(pages, scales, src, dst):
            # copy-on-write: duplicate physical page src into dst across
            # every paged pool (and scale pool) leaf
            return ([p.at[dst].set(p[src]) for p in pages],
                    [s.at[dst].set(s[src]) for s in scales])

        # growing past page_capacity re-traces only these (decode compiles
        # are keyed on the gathered view, whose shape is pool-independent)
        self._gather = jax.jit(gather)
        self._scatter_pages = jax.jit(scatter, donate_argnums=(0, 1))
        self._lane_scatter = jax.jit(lane_scatter, donate_argnums=(0, 1, 2))
        self._page_copy = jax.jit(page_copy, donate_argnums=(0, 1))

    def _build_kernel_jits(self) -> None:
        """Admission-path scatter for the ``pallas_paged`` layout: write a
        freshly prefilled batch-1 cache into the slot's pages and lane.
        This is the only gather/scatter that survives under the kernel
        backend — the decode hot path touches the pools in place."""
        len_axes, batch_axes = self._paged_axis, self._batch_axis
        pps, page, treedef = self.pages_per_slot, self.page_size, \
            self._treedef
        codec = self.codec

        def install(kcache, kscales, cache1, row, i):
            leaves = jax.tree_util.tree_flatten(kcache)[0]
            fresh = jax.tree_util.tree_flatten(cache1)[0]
            sleaves = jax.tree_util.tree_flatten(
                kscales, is_leaf=lambda x: x is None)[0] if codec \
                else [None] * len(leaves)
            out, sout = [], []
            for leaf, src, sleaf, ax, bax in zip(leaves, fresh, sleaves,
                                                 len_axes, batch_axes):
                if ax is not None:
                    # (*lead, 1, L, *rest) -> (*lead, P, page, *rest),
                    # scattered to this slot's physical pages
                    v = src.reshape(*src.shape[:ax - 1], pps, page,
                                    *src.shape[ax + 1:])
                    idx = (slice(None),) * (ax - 1) + (row,)
                    if codec:
                        # page axis sits at ax, features trail it; encode
                        # before padding so zero-padded codes decode to
                        # exactly 0 under the zero-centred codebook
                        v, sc = kv_codec_mod.encode(
                            v, tuple(range(ax + 1, v.ndim)))
                        if sc.shape[-1] != sleaf.shape[-1]:
                            sc = jnp.pad(sc, [(0, 0)] * (sc.ndim - 1)
                                         + [(0, sleaf.shape[-1]
                                             - sc.shape[-1])])
                        sleaf = sleaf.at[idx].set(sc)
                    if v.shape[ax:] != leaf.shape[ax:]:
                        # hardware-tiled pool: zero-fill the sublane (page
                        # row) and lane (trailing feature) padding
                        target = (*v.shape[:ax], *leaf.shape[ax:])
                        v = jnp.pad(v, [(0, dp - dv) for dp, dv
                                        in zip(target, v.shape)])
                else:
                    v = jnp.squeeze(src, axis=bax)
                    idx = (slice(None),) * bax + (i,)
                out.append(leaf.at[idx].set(v.astype(leaf.dtype)))
                sout.append(sleaf)
            new_kcache = jax.tree_util.tree_unflatten(treedef, out)
            if not codec:
                return new_kcache, kscales
            return new_kcache, jax.tree_util.tree_unflatten(treedef, sout)

        def kernel_copy(kcache, kscales, src, dst):
            # copy-on-write in the kernel layout: pool leaves are
            # (*lead, cap, page, *rest) with the physical-page axis at
            # ax - 1; scale leaves are (*lead, cap, page)
            leaves = jax.tree_util.tree_flatten(kcache)[0]
            sleaves = jax.tree_util.tree_flatten(
                kscales, is_leaf=lambda x: x is None)[0] if codec \
                else [None] * len(leaves)
            out, sout = [], []
            for leaf, sleaf, ax in zip(leaves, sleaves, len_axes):
                if ax is not None:
                    s_idx = (slice(None),) * (ax - 1) + (src,)
                    d_idx = (slice(None),) * (ax - 1) + (dst,)
                    leaf = leaf.at[d_idx].set(leaf[s_idx])
                    if codec:
                        sleaf = sleaf.at[d_idx].set(sleaf[s_idx])
                out.append(leaf)
                sout.append(sleaf)
            new_kcache = jax.tree_util.tree_unflatten(treedef, out)
            if not codec:
                return new_kcache, kscales
            return new_kcache, jax.tree_util.tree_unflatten(treedef, sout)

        self._kernel_install = jax.jit(install, donate_argnums=(0, 1))
        self._kernel_copy = jax.jit(kernel_copy, donate_argnums=(0, 1))

        lane_info, n_slots = self._lane_info, self.n_slots

        def lane_snapshot(kcache, poss, k):
            # rows (pos+1+i) % W per lane leaf: the rolling rows draft
            # tokens 0..k-1 will overwrite this step
            leaves = jax.tree_util.tree_flatten(kcache)[0]
            snaps = []
            for li, bax, w in lane_info:
                l2 = jnp.moveaxis(leaves[li], (bax, bax + 1), (0, 1))
                rows = (poss[:, None] + 1 + jnp.arange(k)) % w
                snaps.append(l2[jnp.arange(n_slots)[:, None], rows])
            return snaps

        def lane_restore(kcache, snaps, poss, keep):
            # keep (S, k) bool: restore the snapshotted row (a rejected
            # draft's write must be undone); False leaves the new write
            leaves, treedef = jax.tree_util.tree_flatten(kcache)
            for (li, bax, w), snap in zip(lane_info, snaps):
                l2 = jnp.moveaxis(leaves[li], (bax, bax + 1), (0, 1))
                rows = (poss[:, None] + 1 + jnp.arange(keep.shape[1])) % w
                idx = (jnp.arange(n_slots)[:, None], rows)
                m = keep.reshape(*keep.shape,
                                 *(1,) * (snap.ndim - keep.ndim))
                l2 = l2.at[idx].set(jnp.where(m, snap, l2[idx]))
                leaves[li] = jnp.moveaxis(l2, (0, 1), (bax, bax + 1))
            return jax.tree_util.tree_unflatten(treedef, leaves)

        self._lane_snapshot = jax.jit(lane_snapshot, static_argnums=(2,))
        self._lane_restore = jax.jit(lane_restore, donate_argnums=(0,))

    # -- speculative decoding -----------------------------------------------
    def spec_snapshot(self, poss, k: int):
        """Snapshot the rolling-lane rows draft tokens will overwrite
        (``pallas_paged`` only; no-op without lane leaves)."""
        if not self._lane_info:
            return None
        return self._lane_snapshot(self.kcache, jnp.asarray(poss), k)

    def spec_restore(self, snaps, poss, keep) -> None:
        """Undo rejected drafts' rolling-lane writes: ``keep`` (S, k)
        marks rows to roll back.  Paged leaves self-heal (every position
        is rewritten by the round that covers it before it is attended),
        so only the modular lane rows need this."""
        if snaps is None or not np.asarray(keep).any():
            return
        self.kcache = self._lane_restore(self.kcache, snaps,
                                         jnp.asarray(poss),
                                         jnp.asarray(keep))

    def spec_score(self, params, toks, poss, q_lens):
        """Speculative phase 1 (gathered / monolithic backends): score
        the ragged draft blocks without touching the resident cache ->
        (logits (S, 1, Q, V), opaque commit context).  The scoring pass
        is not donated — its cache output is discarded, which is what
        makes rejection free."""
        assert self.backend != "pallas_paged"
        if self.paged:
            tel = self.engine.telemetry
            table = jnp.asarray(self.table)
            with tel.timed("kv_decode" if self.codec else "kv_gather"):
                views = self._gather(self.pages, self.page_scales,
                                     self.unpaged, table)
            logits, _ = self.engine.verify_slots(
                params, views, toks, poss, q_lens, commit=False,
                kv_quant=self.codec)
            return logits, (views, table)
        logits, _ = self.engine.verify_slots(
            params, self.cache, toks, poss, q_lens, commit=False)
        return logits, None

    def spec_commit(self, params, toks, poss, commit_lens, ctx) -> None:
        """Speculative phase 2: re-run the block at the *accepted*
        lengths with the cache donated — exactly the accepted tokens'
        KV (and recurrent state advance) lands in place, so rollback
        never has to rewind anything."""
        assert self.backend != "pallas_paged"
        if self.paged:
            views, table = ctx
            tel = self.engine.telemetry
            _, new_tree = self.engine.verify_slots(
                params, views, toks, poss, commit_lens, commit=True,
                kv_quant=self.codec)
            with tel.timed("kv_encode" if self.codec else "kv_scatter"):
                self.pages, self.page_scales, self.unpaged = \
                    self._scatter_pages(self.pages, self.page_scales,
                                        new_tree, table)
        else:
            _, self.cache = self.engine.verify_slots(
                params, self.cache, toks, poss, commit_lens, commit=True)

    # -- page bookkeeping ---------------------------------------------------
    def pages_needed(self, cache_len: int) -> int:
        return -(-cache_len // self.page_size) if self.paged else 0

    def pages_in_use(self) -> int:
        return self.allocator.n_allocated if self.paged else 0

    def codec_error_bound(self) -> float:
        """Worst-case elementwise KV reconstruction error of the resident
        pool (max per-token scale / 254); 0.0 when the codec is off."""
        if not self.codec:
            return 0.0
        scales = (jax.tree_util.tree_leaves(self.kscales)
                  if self.backend == "pallas_paged" else self.page_scales)
        top = max((float(jnp.max(s)) for s in scales), default=0.0)
        return float(kv_codec_mod.error_bound(top))

    def _ensure_pages(self, slot: Slot, upto_pos: int) -> None:
        """Allocate table entries so positions [0, upto_pos] are backed."""
        need = upto_pos // self.page_size + 1
        assert need <= self.pages_per_slot, (need, self.pages_per_slot)
        for j in range(need):
            if self.table[slot.index, j] == DUMMY_PAGE:
                self.table[slot.index, j] = self.allocator.alloc()
                slot.reserved_left -= 1
                assert slot.reserved_left >= 0

    def grow_pages(self, n_pages: int) -> None:
        """Grow the logical page pool to ``n_pages`` without touching the
        compiled decode step.

        Growth within ``page_capacity`` is pure free-list bookkeeping — no
        buffer realloc and no re-trace under either backend (the kernel
        backend's compiled decode is keyed on the physical pool shape, so
        capacity headroom is what keeps it stable).  Growth beyond
        capacity reallocates the buffers with geometric headroom; the
        gathered backend then re-traces only its gather/scatter jits,
        while the kernel backend recompiles its decode once per
        capacity doubling."""
        assert self.paged, "grow_pages on a monolithic pool"
        if n_pages <= self.n_pages:
            return
        if n_pages > self.page_capacity:
            new_cap = max(n_pages, 2 * self.page_capacity)
            extra = new_cap - self.page_capacity
            if self.backend == "pallas_paged":
                kleaves = jax.tree_util.tree_flatten(self.kcache)[0]
                out = []
                for leaf, ax in zip(kleaves, self._paged_axis):
                    if ax is not None:
                        pad = jnp.zeros((*leaf.shape[:ax - 1], extra,
                                         *leaf.shape[ax:]), leaf.dtype)
                        leaf = jnp.concatenate([leaf, pad], axis=ax - 1)
                    out.append(leaf)
                self.kcache = jax.tree_util.tree_unflatten(self._treedef,
                                                           out)
                if self.codec:
                    # scale pools are (*lead, cap, page): pad the cap axis
                    self.kscales = jax.tree_util.tree_map(
                        lambda s: jnp.concatenate(
                            [s, jnp.zeros((*s.shape[:-2], extra,
                                           s.shape[-1]), s.dtype)],
                            axis=-2),
                        self.kscales)
            else:
                self.pages = [
                    jnp.concatenate(
                        [p, jnp.zeros((extra, *p.shape[1:]), p.dtype)])
                    for p in self.pages]
                self.page_scales = [
                    jnp.concatenate(
                        [s, jnp.zeros((extra, *s.shape[1:]), s.dtype)])
                    for s in self.page_scales]
            self.page_capacity = new_cap
            if self.backend != "pallas_paged":
                self._build_page_jits()
        self.allocator.add_pages(range(self.n_pages, n_pages))
        self.n_pages = n_pages

    # -- slot queries ---------------------------------------------------
    def free(self) -> list[Slot]:
        return [s for s in self.slots if s.req is None]

    def active(self) -> list[Slot]:
        return [s for s in self.slots if s.req is not None
                and not s.prefilling]

    def prefilling(self) -> list[Slot]:
        return [s for s in self.slots if s.prefilling]

    def busy(self) -> bool:
        return any(s.req is not None for s in self.slots)

    # -- lane install / retire ---------------------------------------------
    def reserve_for(self, slot: Slot, req: Request) -> bool:
        """Reserve every page ``req`` can need; False -> defer admission.

        A mapped prefix discounts the worst case by its fully-covered
        pages only: positions >= ``prefix_matched`` span ``need`` pages
        (a partially-matched boundary page is written and therefore
        copy-on-write'd, costing one fresh allocation like any other).
        Under reservation pressure the prefix index evicts cold entries
        before admission is deferred — mapped pages stay alive through
        the slot's own references."""
        if not self.paged:
            return True
        need = self.pages_needed(
            self.engine.cache_len(req.prompt_len, req.max_new_tokens)) \
            - slot.prefix_matched // self.page_size
        if not self.allocator.reserve(need):
            if self.prefix is None:
                return False
            evicted = self.prefix.evict_until(need)
            if evicted:
                self.engine.metrics.record_prefix_evictions(evicted)
            if not self.allocator.reserve(need):
                return False
        slot.reserved_left = need
        return True

    # -- prefix sharing -----------------------------------------------------
    def map_prefix(self, slot: Slot, req: Request, align: int) -> int:
        """Map the longest cached prefix of ``req``'s prompt into the
        slot's page table (one shared reference per page, owned by the
        slot and released by the normal retire path) -> matched tokens.
        ``align`` is the prefill chunk size: the match is floored to a
        chunk boundary so the computed suffix is bit-identical to the
        sharing-off oracle's."""
        if self.prefix is None:
            return 0
        nodes, matched = self.prefix.lookup(req.prompt,
                                            req.prompt_len - 1, align)
        if not matched:
            return 0
        row = self.table[slot.index]
        for j, node in enumerate(nodes):
            row[j] = self.allocator.share(node.page)
        self.prefix.hit(nodes)
        slot.prefix_matched = matched
        slot._prefix_nodes = nodes
        return matched

    def unmap_prefix(self, slot: Slot) -> None:
        """Roll back :meth:`map_prefix` (reservation failure path)."""
        if not slot.prefix_matched:
            return
        row = self.table[slot.index]
        n = -(-slot.prefix_matched // self.page_size)
        self.allocator.release(int(row[j]) for j in range(n))
        row[:n] = DUMMY_PAGE
        slot.prefix_matched = 0
        slot._prefix_nodes = None

    def seed_pcache(self, slot: Slot) -> None:
        """Write the mapped prefix's raw-fp fragments into the slot's
        fresh standalone prefill cache at positions [0, matched) exactly
        — bit-identical to what the sharing-off chunk loop would have
        computed there (gathered backend only; the mixed-step path reads
        the shared pool pages in place)."""
        matched = slot.prefix_matched
        if not matched or slot.pcache is None:
            return
        leaves, treedef = jax.tree_util.tree_flatten(slot.pcache)
        P = self.page_size
        for k, node in enumerate(slot._prefix_nodes):
            lo, hi = k * P, min((k + 1) * P, matched)
            if hi <= lo:
                break
            pi = 0
            for li, ax in enumerate(self._paged_axis):
                if ax is None:
                    continue
                frag = node.frag[pi]
                pi += 1
                sub = frag[(slice(None),) * ax + (slice(0, hi - lo),)]
                leaves[li] = leaves[li].at[
                    (slice(None),) * ax + (slice(lo, hi),)].set(
                    jnp.asarray(sub))
        slot.pcache = jax.tree_util.tree_unflatten(treedef, leaves)

    def register_prefix(self, slot: Slot, cache1=None) -> None:
        """Insert a just-prefilled slot's pages into the prefix index:
        full prompt pages plus the partial boundary page (its tail holds
        positions the mapping masks never expose; the first write by the
        owning slot copy-on-writes away from it, funded by one extra
        reservation taken here).  ``cache1`` is the gathered backend's
        completed standalone cache, snapshotted into raw-fp fragments
        before install quantised it into the pool."""
        if self.prefix is None:
            return
        req = slot.req
        L, P = req.prompt_len, self.page_size
        row = self.table[slot.index]
        frags = self._extract_frags(cache1, -(-L // P)) \
            if cache1 is not None else None
        if L % P and self.allocator.reserve(1):
            if self.prefix.register(req.prompt, row, frags=frags,
                                    allow_partial=True):
                slot.reserved_left += 1
            else:
                self.allocator.unreserve(1)
        else:
            self.prefix.register(req.prompt, row, frags=frags,
                                 allow_partial=False)

    def _extract_frags(self, cache1, n_pages: int) -> list:
        """Host copies of each paged leaf's per-page slices of a
        standalone batch-1 cache -> frags[page][leaf]."""
        leaves = jax.tree_util.tree_flatten(cache1)[0]
        P = self.page_size
        frags = []
        for j in range(n_pages):
            per_leaf = []
            for leaf, ax in zip(leaves, self._paged_axis):
                if ax is None:
                    continue
                per_leaf.append(np.asarray(
                    leaf[(slice(None),) * ax
                         + (slice(j * P, (j + 1) * P),)]))
            frags.append(per_leaf)
        return frags

    def _prepare_write(self, slot: Slot, lo_pos: int, hi_pos: int) -> None:
        """Copy-on-write barrier: before positions [lo_pos, hi_pos] are
        written, any shared page backing them (refcount >= 2: the prefix
        index and/or another slot also reference it) is duplicated into a
        fresh private page and swapped into this slot's table row.  Draws
        on the slot's reservation like any other allocation, so it cannot
        fail mid-request."""
        if self.prefix is None:
            return
        row = self.table[slot.index]
        P = self.page_size
        for j in range(lo_pos // P, hi_pos // P + 1):
            pid = int(row[j])
            if pid == DUMMY_PAGE or self.allocator.refcount(pid) < 2:
                continue
            new = self.allocator.alloc()
            slot.reserved_left -= 1
            assert slot.reserved_left >= 0
            self._copy_page(pid, new)
            row[j] = new
            self.allocator.release([pid])
            self.engine.metrics.record_prefix_cow()

    def _copy_page(self, src: int, dst: int) -> None:
        with self.engine.telemetry.timed("kv_cow"):
            if self.backend == "pallas_paged":
                self.kcache, self.kscales = self._kernel_copy(
                    self.kcache, self.kscales, jnp.int32(src),
                    jnp.int32(dst))
            else:
                self.pages, self.page_scales = self._page_copy(
                    self.pages, self.page_scales, jnp.int32(src),
                    jnp.int32(dst))

    def install(self, slot: Slot, cache1, tok: int) -> None:
        """Write a freshly prefilled batch-1 cache into the slot's lane and
        flip it to ACTIVE with first token ``tok``."""
        req = slot.req
        end = self.engine.pos_offset(req.prompt_len)   # positions < end used
        if self.paged:
            # install rewrites the whole row: positions < prefix_matched
            # carry bit-identical bytes (the pcache was seeded from the
            # cached prefix's raw-fp fragments, and the codec encodes
            # per-token), so fully-matched shared pages are safe to
            # rewrite in place — only the partially-matched boundary
            # page (written with this request's own suffix) needs the
            # copy-on-write barrier
            self._prepare_write(slot, slot.prefix_matched,
                                max(end - 1, slot.prefix_matched))
            self._ensure_pages(slot, max(end - 1, 0))
            row = jnp.asarray(self.table[slot.index])
            if self.backend == "pallas_paged":
                self.kcache, self.kscales = self._kernel_install(
                    self.kcache, self.kscales, cache1, row,
                    jnp.int32(slot.index))
            else:
                self.pages, self.page_scales, self.unpaged = \
                    self._lane_scatter(
                        self.pages, self.page_scales, self.unpaged, cache1,
                        row, jnp.int32(slot.index))
        else:
            self.cache = self._scatter(self.cache, cache1,
                                       jnp.int32(slot.index))
        slot.prefilling = False
        slot.pcache = None
        slot.tok = tok
        slot.pos = end
        # install is the prefill path's cache copy (pool/lane scatter of
        # the standalone prefill cache) — counted so the mixed-step path
        # can assert it moved nothing
        self.engine.metrics.record_prefill_gather(self.install_bytes, 0)

    def retire(self, slot: Slot) -> None:
        """Release the slot's lane, pages, and outstanding reservations."""
        if self.paged:
            row = self.table[slot.index]
            self.allocator.release(int(p) for p in row if p != DUMMY_PAGE)
            row[:] = DUMMY_PAGE
            if slot.reserved_left:
                self.allocator.unreserve(slot.reserved_left)
        slot.reserved_left = 0
        slot.prefilling = False
        slot.pcache = None
        slot.prefix_matched = 0
        slot._prefix_nodes = None
        slot.req = None

    # -- mixed step (pallas_paged): prefill chunks + decode, one trace ------
    def mixed_step(self, params, toks, poss, q_lens):
        """One ragged mixed step over the donated pools: toks (S, Q),
        poss (S,) start positions, q_lens (S,) real token counts (0 =
        free lane) -> logits (S, Q, V).  Pages backing every written
        position must already be ensured by the caller."""
        assert self.backend == "pallas_paged"
        if self.codec:
            logits, self.kcache, self.kscales = self.engine.mixed_step(
                params, self.kcache, jnp.asarray(self.table),
                jnp.asarray(toks, dtype=jnp.int32), jnp.asarray(poss),
                jnp.asarray(q_lens), paged_flags=self.paged_flags,
                page_size=self.page_size, q_block=self.q_block,
                pages_per_step=self.pages_per_step,
                kv_scales=self.kscales)
        else:
            logits, self.kcache = self.engine.mixed_step(
                params, self.kcache, jnp.asarray(self.table),
                jnp.asarray(toks, dtype=jnp.int32), jnp.asarray(poss),
                jnp.asarray(q_lens), paged_flags=self.paged_flags,
                page_size=self.page_size, q_block=self.q_block,
                pages_per_step=self.pages_per_step)
        return logits

    # -- decode -------------------------------------------------------------
    def decode(self, params) -> list[tuple[Slot, int, bool]]:
        """One decode step for every slot -> per active slot (slot, next
        token, logits_finite); advances each active slot's (tok, pos).

        Backend seam: ``gathered`` gathers pages into contiguous views,
        runs the vmapped per-slot decode, and scatters the pages back;
        ``pallas_paged`` hands the donated pools + page table straight to
        the paged decode step — zero per-step cache copies."""
        active = self.active()
        toks = np.zeros((self.n_slots, 1, 1), np.int32)
        poss = np.zeros(self.n_slots, np.int32)
        q_lens = np.zeros(self.n_slots, np.int32)
        for s in active:
            toks[s.index, 0, 0] = s.tok
            poss[s.index] = s.pos
            q_lens[s.index] = 1
            if self.paged:
                # a registered request's partial boundary page is shared
                # with the prefix index: the decode append must land on a
                # private copy
                self._prepare_write(s, s.pos, s.pos)
                self._ensure_pages(s, s.pos)   # page for this step's write
        if self.backend == "pallas_paged":
            logits = self.mixed_step(params, toks[:, :, 0], poss, q_lens)
            last = logits[:, -1]                          # (S, V)
        elif self.paged:
            tel = self.engine.telemetry
            table = jnp.asarray(self.table)
            with tel.timed("kv_decode" if self.codec else "kv_gather"):
                views = self._gather(self.pages, self.page_scales,
                                     self.unpaged, table)
            logits, new_tree = self.engine.slot_decode(
                params, views, jnp.asarray(toks), jnp.asarray(poss),
                kv_quant=bool(self.codec))
            with tel.timed("kv_encode" if self.codec else "kv_scatter"):
                self.pages, self.page_scales, self.unpaged = \
                    self._scatter_pages(self.pages, self.page_scales,
                                        new_tree, table)
            last = logits[:, 0, -1]                       # (S, V)
        else:
            logits, self.cache = self.engine.slot_decode(
                params, self.cache, jnp.asarray(toks), jnp.asarray(poss))
            last = logits[:, 0, -1]                       # (S, V)
        nxt = np.asarray(jnp.argmax(last, axis=-1)).astype(np.int32)
        finite = np.asarray(jnp.isfinite(last).all(axis=-1))
        out = []
        for s in active:
            s.pos += 1
            s.tok = int(nxt[s.index])
            out.append((s, s.tok, bool(finite[s.index])))
        return out


class Scheduler:
    """Admit -> (chunked or monolithic) per-slot prefill -> vmapped
    continuous decode.

    ``mode="continuous"`` (default): admit-on-retire — any freed slot is
    refilled from the queue before the next decode step.
    ``mode="wave"``: the old wave-granular scheduling as a slot config —
    admission waits until every slot has drained, and each admission round
    takes up to ``batch_size`` queued requests sharing the head request's
    length bucket (the old grouping).

    ``prefill_chunk=N`` splits each admitted prompt into N-token chunks
    interleaved with decode steps; ``prefill_budget`` caps prefill tokens
    per scheduler iteration (default: one chunk).  ``kv_page_size=N``
    backs the KV lanes with N-token pages (``kv_pages`` overrides the
    logical pool size; default fully backs every slot;
    ``kv_page_capacity`` pre-sizes the physical buffers so ``grow_pages``
    up to it never recompiles decode).

    ``attn_backend`` picks how decode reads the paged KV: ``"gathered"``
    (default — copy pages into contiguous per-slot views each step, the
    reference oracle) or ``"pallas_paged"`` (the in-kernel paged-attention
    backend: requires ``kv_page_size``; archs without attention-style
    caches fall back to ``gathered`` with a RuntimeWarning naming the
    capability probe that failed — warned once per family — plus the
    emitted note, like the chunked-prefill fallback).  Both backends are
    token-identical by test.

    ``attn_backend="pallas_paged"`` together with ``prefill_chunk``
    engages the unified **mixed-step** path: every scheduler iteration,
    active slots contribute their decode token and prefilling slots up to
    one prompt chunk to a *single* ragged ``mixed_step`` trace over the
    donated page pools.  There is no standalone prefill cache and no
    install copy — per-iteration KV gather bytes are zero on the prefill
    and decode paths alike, and the gathered chunk loop below survives as
    the token-identical oracle.
    """

    def __init__(self, engine: ServeEngine, *, batch_size: int = 4,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 mode: str = "continuous", slot_len: int | None = None,
                 prefill_chunk: int | None = None,
                 prefill_budget: int | None = None,
                 kv_page_size: int | None = None,
                 kv_pages: int | None = None,
                 kv_page_capacity: int | None = None,
                 attn_backend: str = "gathered",
                 kv_codec: str = "none",
                 prefix_share: bool = False,
                 kernel_tune: str | None = None,
                 speculate: str = "off", draft_k: int = 4,
                 log_every: int = 0, emit: Callable[[str], None] = print):
        if mode not in ("continuous", "wave"):
            raise ValueError(f"unknown scheduling mode {mode!r}")
        if draft_k < 1:
            raise ValueError(f"draft_k must be >= 1: {draft_k}")
        if prefill_chunk is not None and prefill_chunk <= 0:
            raise ValueError(f"prefill_chunk must be positive: "
                             f"{prefill_chunk}")
        if attn_backend not in ATTN_BACKENDS:
            raise ValueError(f"unknown attention backend {attn_backend!r}; "
                             f"choose from {ATTN_BACKENDS}")
        if attn_backend == "pallas_paged" and kv_page_size is None:
            raise ValueError("attn_backend='pallas_paged' needs paged KV "
                             "lanes; set kv_page_size")
        if kv_codec not in KV_CODECS:
            raise ValueError(f"unknown kv codec {kv_codec!r}; "
                             f"choose from {KV_CODECS}")
        if kv_codec == "cluster" and kv_page_size is None:
            raise ValueError("kv_codec='cluster' compresses the page "
                             "pools; set kv_page_size")
        if prefix_share and kv_page_size is None:
            raise ValueError("prefix_share maps shared KV pages; set "
                             "kv_page_size")
        if prefix_share and prefill_chunk is None:
            raise ValueError("prefix_share skips prefill chunk by chunk; "
                             "set prefill_chunk")
        kernel_tune = kernel_tune or "off"
        if kernel_tune != "off" and attn_backend != "pallas_paged":
            raise ValueError("kernel_tune shapes the pallas_paged kernel "
                             "launch; set attn_backend='pallas_paged' or "
                             "leave it 'off'")
        if kernel_tune not in ("auto", "off"):
            try:
                parts = [int(p) for p in kernel_tune.split(",")]
                assert 1 <= len(parts) <= 2 and min(parts) >= 0
            except (ValueError, AssertionError):
                raise ValueError(
                    f"unknown kernel_tune {kernel_tune!r}; choose 'auto', "
                    "'off', or explicit 'Q_BLOCK[,PAGES_PER_STEP]'")
        self.engine = engine
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.mode = mode
        self.slot_len = slot_len
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget or prefill_chunk
        self.kv_page_size = kv_page_size
        self.kv_pages = kv_pages
        self.kv_page_capacity = kv_page_capacity
        self.attn_backend = attn_backend
        self.kv_codec = kv_codec
        self.prefix_share = prefix_share
        self.kernel_tune = kernel_tune
        self.speculate = speculate or "off"
        self.draft_k = int(draft_k)
        self.drafter = None
        self.log_every = log_every
        self.emit = emit
        self._queue: list[Request] = []
        self._pool: SlotPool | None = None
        self._next_rid = 0
        if prefill_chunk is not None and \
                not engine.supports_chunked_prefill:
            self.prefill_chunk = None
            _warn_fallback(
                engine.cfg.family, "chunked_prefill",
                f"{engine.cfg.family} arch downgraded to monolithic "
                f"prefill: supports_chunked_prefill=False (a multimodal "
                f"prefix cannot resume a prompt mid-cache)")
            emit(f"note: {engine.cfg.family} arch cannot resume a prompt "
                 "mid-cache; falling back to monolithic prefill")
        if self.speculate != "off" and (
                not supports_speculation(engine.cfg) or
                engine.api.verify_step is None):
            self.speculate = "off"
            _warn_fallback(
                engine.cfg.family, "speculation",
                f"{engine.cfg.family} arch downgraded to plain decoding: "
                f"supports_speculation=False (draft verification rides "
                f"the resume-from-cache machinery this arch lacks)")
            emit(f"note: {engine.cfg.family} arch cannot verify draft "
                 "tokens mid-cache; speculative decoding off")
        if self.speculate != "off":
            from repro.runtime.drafter import make_drafter
            self.drafter = make_drafter(self.speculate, engine)
        if attn_backend == "pallas_paged" and \
                not engine.supports_paged_attention:
            self.attn_backend = "gathered"
            self.kernel_tune = "off"
            _warn_fallback(
                engine.cfg.family, "paged_attention",
                f"{engine.cfg.family} arch downgraded to the gathered "
                f"attention backend: supports_paged_attention=False (no "
                f"attention-style cache to page)")
            emit(f"note: {engine.cfg.family} arch has no paged decode "
                 "attention; falling back to the gathered backend")
        if self.prefix_share and (self.prefill_chunk is None or
                                  not supports_prefix_share(engine.cfg)):
            self.prefix_share = False
            _warn_fallback(
                engine.cfg.family, "prefix_share",
                f"{engine.cfg.family} arch downgraded to unshared KV "
                f"pages: supports_prefix_share=False (prefix sharing "
                f"needs chunked prefill and every cache leaf paged)")
            emit(f"note: {engine.cfg.family} arch cannot map shared "
                 "prefix pages; serving each request's KV privately")

    # -- admission ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int32).ravel()
        if prompt.shape[0] > self.buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds the largest "
                f"length bucket ({self.buckets[-1]}); truncate the prompt "
                f"or configure larger buckets")
        req = Request(self._next_rid, prompt, int(max_new_tokens),
                      t_submit=time.monotonic())
        self._next_rid += 1
        self._queue.append(req)
        return req

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _wave_group(self) -> list[Request]:
        """Up to batch_size queued requests sharing the head's bucket."""
        head_bucket = self._bucket(self._queue[0].prompt_len)
        group, rest = [], []
        for req in self._queue:
            if len(group) < self.batch_size and \
                    self._bucket(req.prompt_len) == head_bucket:
                group.append(req)
            else:
                rest.append(req)
        self._queue = rest
        return group

    def _ensure_pool(self) -> SlotPool:
        """(Re)build the pool when the queue needs longer slot caches;
        reuse it otherwise so compiled decode shapes carry across runs."""
        eng = self.engine
        needed = max(eng.cache_len(r.prompt_len, r.max_new_tokens)
                     for r in self._queue)
        slot_len = self.slot_len or \
            -(-needed // SLOT_LEN_QUANTUM) * SLOT_LEN_QUANTUM
        if self._pool is None or self._pool.slot_len < slot_len or \
                self._pool.n_slots != self.batch_size:
            slot_len = max(slot_len, self._pool.slot_len if self._pool
                           else 0)
            q_block, pages_per_step, hw_tiles = \
                self._resolve_kernel_tune(slot_len)
            self._pool = SlotPool(eng, self.batch_size, slot_len,
                                  page_size=self.kv_page_size,
                                  n_pages=self.kv_pages,
                                  backend=self.attn_backend,
                                  page_capacity=self.kv_page_capacity,
                                  kv_codec=self.kv_codec,
                                  prefix_share=self.prefix_share,
                                  q_block=q_block,
                                  pages_per_step=pages_per_step,
                                  hw_tiles=hw_tiles)
        return self._pool

    def _resolve_kernel_tune(self, slot_len: int) -> tuple[int, int, bool]:
        """``kernel_tune`` -> (q_block, pages_per_step, hw_tiles) for the
        pool about to be built.

        ``"off"`` keeps the identity layout (no padding, one page per
        grid step, whole-Q blocks); any other value turns hardware
        tiling on.  ``"auto"`` sweeps the live ``(arch, page, Q)`` point
        through :func:`runtime.autotune.tune_kernel` (memoised per key);
        ``"QB[,PPS]"`` pins the launch shape explicitly."""
        if self.kernel_tune == "off" or self.attn_backend != "pallas_paged":
            return 0, 1, False
        if self.kernel_tune != "auto":
            parts = [int(p) for p in self.kernel_tune.split(",")]
            return parts[0], parts[1] if len(parts) > 1 else 1, True
        from repro.runtime.autotune import tune_kernel
        width = min(self.prefill_chunk, slot_len) \
            if self.prefill_chunk else 1
        res = tune_kernel(self.engine.cfg, self.kv_page_size, width,
                          codec=self.kv_codec == "cluster",
                          interpret=self.engine.kernel_interpret)
        self.emit(f"kernel autotune {res['key']}: q_block={res['q_block']} "
                  f"pages_per_step={res['pages_per_step']} "
                  f"({res['best_ms']:.3f} ms/step"
                  f"{', cached' if res['cached'] else ''})")
        return res["q_block"], res["pages_per_step"], True

    # -- serving -----------------------------------------------------------
    def run(self) -> list[Request]:
        """Serve the queue to completion -> completed requests."""
        if not self._queue:
            return []
        tel = self.engine.telemetry
        completed: list[Request] = []
        pool = self._ensure_pool()
        while self._queue or pool.busy():
            if self._queue:
                with tel.timed("admit"):
                    self._admit(pool, completed)
            if self._mixed_path(pool):
                with tel.timed("mixed_step"):
                    self._mixed_tick(pool, completed)
            else:
                if pool.prefilling():
                    with tel.timed("prefill"):
                        self._prefill_tick(pool, completed)
                if pool.active():
                    if self.drafter is not None:
                        if pool.backend == "pallas_paged":
                            # single-phase in-kernel speculation: the
                            # mixed tick verifies drafts even with no
                            # chunks in flight
                            with tel.timed("mixed_step"):
                                self._mixed_tick(pool, completed)
                        else:
                            self._spec_step(pool, completed)
                    else:
                        with tel.timed("decode"):
                            self._step(pool, completed)
        if pool.codec:
            self.engine.metrics.record_kv_codec_error(
                pool.codec_error_bound())
        return completed

    def _mixed_path(self, pool: SlotPool) -> bool:
        """True when serving runs the unified mixed-step path: prefill
        chunks and decode tokens of every slot ride one batched
        ``mixed_step`` trace per iteration, writing straight into the
        page pools (``pallas_paged`` + chunked prefill; the gathered
        backend keeps the standalone-cache chunk loop as the
        token-identical oracle)."""
        return pool.backend == "pallas_paged" and \
            self.prefill_chunk is not None

    def _trace_admitted(self, req: Request, slot: Slot) -> None:
        """Close the request's queued span and mark its admission."""
        req.t_admit = time.monotonic()
        tr = self.engine.telemetry.tracer
        if tr.enabled:
            tr.name_track(PID_REQUEST, req.rid, f"request {req.rid}")
            tr.complete(PID_REQUEST, req.rid, "queued", req.t_submit,
                        req.t_admit, prompt_len=req.prompt_len)
            tr.instant(PID_REQUEST, req.rid, "admitted", req.t_admit,
                       slot=slot.index, backend=self.attn_backend)

    def _record_first_token(self, req: Request, tok: int) -> None:
        req.generated.append(tok)
        req.t_first = time.monotonic()
        self.engine.metrics.record_ttft(req.t_first - req.t_submit)
        tr = self.engine.telemetry.tracer
        if tr.enabled:
            tr.instant(PID_REQUEST, req.rid, "first_token", req.t_first,
                       token=tok)

    def _start_or_admit(self, pool: SlotPool, req: Request, params,
                        completed: list[Request]) -> None:
        """Place ``req`` in a free slot: chunked -> PREFILLING state,
        monolithic -> full prefill now (the PR-2 admission path)."""
        m = self.engine.metrics
        slot = pool.free()[0]
        if self.engine.cache_len(req.prompt_len, req.max_new_tokens) \
                > pool.slot_len:
            raise ValueError(
                f"request {req.rid} needs "
                f"{self.engine.cache_len(req.prompt_len, req.max_new_tokens)}"
                f" cache positions > slot_len {pool.slot_len}")
        if self.prefill_chunk is not None:
            slot.req = req
            slot.prefilling = True
            # a mapped prefix starts the chunk cursor past the cached
            # span — those prompt tokens cost zero prefill work
            slot.prefill_cursor = slot.prefix_matched
            # mixed-step prefill writes chunks straight into the slot's
            # pages/lane — no standalone batch-1 cache exists at all
            slot.pcache = None if self._mixed_path(pool) else \
                self.engine.fresh_slot_cache(pool.slot_len)
            if slot.prefix_matched:
                pool.seed_pcache(slot)
                m.record_prefix_hit(
                    slot.prefix_matched,
                    slot.prefix_matched // self.prefill_chunk)
            self._trace_admitted(req, slot)
            if slot.prefix_matched:
                tr = self.engine.telemetry.tracer
                if tr.enabled:
                    tr.instant(PID_REQUEST, req.rid, "prefix_hit",
                               req.t_admit, tokens=slot.prefix_matched)
            return
        t0 = time.monotonic()
        slot.req = req
        self._trace_admitted(req, slot)
        tok, cache1 = self.engine.prefill_request(params, req.prompt,
                                                  pool.slot_len)
        pool.install(slot, cache1, tok)
        t1 = time.monotonic()
        tr = self.engine.telemetry.tracer
        if tr.enabled:
            tr.complete(PID_REQUEST, req.rid, "prefill", t0, t1,
                        slot=slot.index, tokens=req.prompt_len)
        self._record_first_token(req, tok)
        m.record_admit(1, t1 - t0, tokens=1)
        self._maybe_finish(pool, slot, completed)

    def _maybe_finish(self, pool: SlotPool, slot: Slot,
                      completed: list[Request]) -> None:
        req = slot.req
        if len(req.generated) >= req.max_new_tokens:
            req.done = True
            req.t_done = time.monotonic()
            tr = self.engine.telemetry.tracer
            if tr.enabled:
                pages = int((pool.table[slot.index] != DUMMY_PAGE).sum()) \
                    if pool.paged else 0
                if req.t_first is not None:
                    tr.complete(PID_REQUEST, req.rid, "decode",
                                req.t_first, req.t_done, slot=slot.index,
                                tokens=len(req.generated),
                                pages_held=pages)
                tr.complete(PID_REQUEST, req.rid, "request", req.t_submit,
                            req.t_done, prompt_len=req.prompt_len,
                            tokens=len(req.generated),
                            backend=self.attn_backend)
                tr.instant(PID_REQUEST, req.rid, "retired", req.t_done,
                           slot=slot.index)
            pool.retire(slot)
            completed.append(req)
            self.engine.metrics.record_completed(1)
            self.engine.metrics.record_request_done(req)

    def _admit(self, pool: SlotPool, completed: list[Request]) -> None:
        m = self.engine.metrics
        if self.mode == "wave":
            if pool.busy() or not self._queue:
                return                    # wave mode: drain before admitting
            group = self._wave_group()[: pool.n_slots]
            m.record_wave()
        else:
            group = None                  # continuous: straight FIFO
        while self._queue or group:
            if group is not None:
                if not group:
                    return
                req = group[0]
            else:
                if not pool.free():
                    return
                req = self._queue[0]
            slot = pool.free()[0] if pool.free() else None
            ok = False
            if slot is not None:
                matched = pool.map_prefix(slot, req,
                                          self.prefill_chunk or 1)
                ok = pool.reserve_for(slot, req)
                if not ok and matched:
                    # a hit whose *remaining* pages cannot be reserved is
                    # rolled back — the request may still fit unshared
                    # (mapped pages themselves occupy free-list capacity)
                    pool.unmap_prefix(slot)
                    ok = pool.reserve_for(slot, req)
            if not ok:
                if slot is not None and not pool.busy():
                    # idle pool that still can't reserve: no retire will
                    # ever free pages, so deferring would spin forever
                    need = pool.pages_needed(self.engine.cache_len(
                        req.prompt_len, req.max_new_tokens))
                    raise ValueError(
                        f"request {req.rid} needs {need} KV pages but "
                        f"the pool only has {pool.allocator.total}; "
                        f"raise kv_pages")
                # paged pool under pressure: keep FIFO order, admit when
                # a retire returns pages (reservation makes this safe)
                if group is not None:
                    self._queue = group + self._queue
                return
            (group or self._queue).pop(0)
            params = self.engine.step_params()
            self._start_or_admit(pool, req, params, completed)

    def _prefill_tick(self, pool: SlotPool, completed: list[Request]) -> None:
        """Advance chunked prefills by up to ``prefill_budget`` prompt
        tokens (whole chunks; at least one per tick for progress) — the
        gathered oracle's chunk loop, each prefilling slot on its
        standalone batch-1 cache (the ``pallas_paged`` backend runs
        chunks through :meth:`_mixed_tick` instead).

        Chunks round-robin across prefilling slots so a short prompt
        admitted next to a long one reaches its first token after its own
        few chunks instead of queueing behind the long prompt's."""
        if self.prefill_chunk is None:
            return
        m = self.engine.metrics
        budget = self.prefill_budget
        spent = 0
        pending = pool.prefilling()
        while pending and spent < budget:
            for slot in pending:
                if spent >= budget:
                    break
                req = slot.req
                c = min(self.prefill_chunk,
                        req.prompt_len - slot.prefill_cursor)
                chunk = req.prompt[slot.prefill_cursor:
                                   slot.prefill_cursor + c]
                t0 = time.monotonic()
                params = self.engine.step_params()
                # under a KV codec the chunk's K/V is codec-roundtripped
                # in the standalone cache so install's re-encode lands on
                # the codec's own fixed point — bit-identical to the
                # monolithic prefill's single encode
                logits, slot.pcache = self.engine.prefill_chunk_step(
                    params, slot.pcache, chunk, slot.prefill_cursor,
                    kv_quant=bool(pool.codec))
                dt = time.monotonic() - t0
                m.record_prefill_chunk(c, dt, stalled=bool(pool.active()))
                tr = self.engine.telemetry.tracer
                if tr.enabled:
                    tr.complete(PID_REQUEST, req.rid, "prefill_chunk",
                                t0, t0 + dt, slot=slot.index, tokens=c,
                                cursor=slot.prefill_cursor)
                slot.prefill_cursor += c
                spent += c
                if slot.prefill_cursor >= req.prompt_len:
                    if not bool(jnp.isfinite(logits[0, -1]).all()):
                        raise RuntimeError(
                            "non-finite prefill logits (compressed "
                            "reconstruction or model numerics are broken)")
                    tok = int(jnp.argmax(logits[0, -1]))
                    # install clears pcache; the prefix index snapshots
                    # its raw-fp pages (install is not donated cache1)
                    cache1 = slot.pcache
                    pool.install(slot, cache1, tok)
                    pool.register_prefix(slot, cache1)
                    self._record_first_token(req, tok)
                    m.record_admit(1, 0.0, tokens=1)
                    self._maybe_finish(pool, slot, completed)
            pending = [s for s in pending if s.prefilling]

    def _mixed_tick(self, pool: SlotPool,
                    completed: list[Request]) -> None:
        """One iteration of the unified mixed-step path: every active
        slot contributes its decode token and every prefilling slot up to
        one prompt chunk, all through a single ragged ``mixed_step``
        trace over the donated page pools.  ``prefill_budget`` caps the
        *total* chunk tokens admitted to the trace (always at least one
        chunk for progress); unlike the gathered chunk loop, a slot can
        never advance more than ``prefill_chunk`` tokens per iteration —
        the trace width Q is bounded, so budget beyond
        ``n_prefilling * prefill_chunk`` has no additional effect.

        There is no standalone prefill cache and no install copy — chunk
        K/V lands straight in the slot's pages (lane leaves are written
        in the same trace with ragged masks) — so per-iteration KV gather
        bytes are zero on the prefill and decode paths alike, which the
        metrics record and tests assert."""
        m = self.engine.metrics
        active = pool.active()
        chunks: list[tuple[Slot, int]] = []
        spent = 0
        for slot in pool.prefilling():
            if spent >= self.prefill_budget and chunks:
                break
            c = min(self.prefill_chunk,
                    slot.req.prompt_len - slot.prefill_cursor)
            chunks.append((slot, c))
            spent += c
        if not active and not chunks:
            return
        drafts: dict[int, np.ndarray] = {}
        if self.drafter is not None and active:
            # rolling-window lanes are snapshot/restored around the
            # trace; the snapshot depth caps how deep a draft may write
            cap = None if pool.lane_min_rows is None \
                else pool.lane_min_rows - 1
            with self.engine.telemetry.timed("spec_draft"):
                drafts = self._propose_drafts(pool, active, cap=cap)
        # pad every chunk-carrying tick to one block width so compiled
        # mixed-step shapes stay bounded: Q = prefill_chunk while chunks
        # are in flight (remainders ride padded; drafts fold into the
        # same padding), Q = 1 + draft_k on speculative decode ticks,
        # Q = 1 for plain decode
        width = min(self.prefill_chunk, pool.slot_len) if chunks else 1
        if chunks:
            drafts = {i: d[:width - 1] for i, d in drafts.items()}
        drafts = {i: d for i, d in drafts.items() if len(d)}
        if drafts and not chunks:
            width = 1 + self.draft_k
        toks = np.zeros((pool.n_slots, width), np.int32)
        poss = np.zeros(pool.n_slots, np.int32)
        q_lens = np.zeros(pool.n_slots, np.int32)
        for slot in active:
            d = drafts.get(slot.index)
            nd = 0 if d is None else len(d)
            toks[slot.index, 0] = slot.tok
            if nd:
                toks[slot.index, 1:1 + nd] = d
            poss[slot.index] = slot.pos
            q_lens[slot.index] = 1 + nd
            pool._prepare_write(slot, slot.pos, slot.pos + nd)
            pool._ensure_pages(slot, slot.pos + nd)
        for slot, c in chunks:
            cur = slot.prefill_cursor
            toks[slot.index, :c] = slot.req.prompt[cur:cur + c]
            poss[slot.index] = cur
            q_lens[slot.index] = c
            # chunk K/V lands in the pool in place: shared pages under
            # the write range must be copy-on-write'd first
            pool._prepare_write(slot, cur, cur + c - 1)
            pool._ensure_pages(slot, cur + c - 1)
        t0 = time.monotonic()
        params = self.engine.step_params()
        snaps = kk = None
        if drafts and pool.lane_min_rows is not None:
            # rolling-window lanes have no rewind: snapshot the rows the
            # drafts will overwrite so rejected writes can be undone
            kk = max(len(d) for d in drafts.values())
            snaps = pool.spec_snapshot(poss, kk)
        logits = pool.mixed_step(params, toks, poss, q_lens)
        g = np.asarray(jnp.argmax(logits, axis=-1))              # (S, Q)
        ok_rows = np.asarray(jnp.isfinite(logits).all(axis=-1))  # (S, Q)
        lanes = np.arange(pool.n_slots)
        nxt = g[lanes, np.maximum(q_lens - 1, 0)].astype(np.int32)
        finite = ok_rows[lanes, np.maximum(q_lens - 1, 0)]
        dt = time.monotonic() - t0
        # wall time attributed to decode vs prefill by token share
        n_chunk_toks = sum(c for _, c in chunks)
        n_dec_toks = int(sum(q_lens[s.index] for s in active))
        total = n_dec_toks + n_chunk_toks
        dt_decode = dt * n_dec_toks / total if total else 0.0
        emitted = 0
        acc: dict[int, int] = {}
        for slot in active:
            d = drafts.get(slot.index)
            nd = 0 if d is None else len(d)
            a = 0
            while a < nd and int(d[a]) == int(g[slot.index, a]):
                a += 1
            acc[slot.index] = a
            if not ok_rows[slot.index, :a + 1].all():
                raise RuntimeError(
                    f"non-finite logits in mixed step for request "
                    f"{slot.req.rid} (compressed reconstruction or model "
                    f"numerics are broken)")
            for t in g[slot.index, :a + 1]:
                slot.req.generated.append(int(t))
            emitted += a + 1
            slot.pos += a + 1
            slot.tok = int(g[slot.index, a])
            if nd:
                m.record_spec(nd, a)
            self._maybe_finish(pool, slot, completed)
        if snaps is not None:
            with self.engine.telemetry.timed("spec_rollback"):
                keep = np.zeros((pool.n_slots, kk), bool)
                for slot in active:
                    d = drafts.get(slot.index)
                    if d is not None:
                        keep[slot.index, acc[slot.index]:len(d)] = True
                pool.spec_restore(snaps, poss, keep)
        tr = self.engine.telemetry.tracer
        for slot, c in chunks:
            m.record_prefill_chunk(c, (dt - dt_decode) / len(chunks),
                                   stalled=bool(active))
            if tr.enabled:
                # chunks share one ragged trace; each request's span
                # covers the tick's prefill share
                tr.complete(PID_REQUEST, slot.req.rid, "prefill_chunk",
                            t0, t0 + (dt - dt_decode), slot=slot.index,
                            tokens=c, cursor=slot.prefill_cursor)
            slot.prefill_cursor += c
            if slot.prefill_cursor >= slot.req.prompt_len:
                if not finite[slot.index]:
                    raise RuntimeError(
                        "non-finite prefill logits (compressed "
                        "reconstruction or model numerics are broken)")
                req = slot.req
                slot.prefilling = False
                slot.pcache = None
                slot.tok = int(nxt[slot.index])
                slot.pos = self.engine.pos_offset(req.prompt_len)
                # mixed-step pages hold the kernel-written (possibly
                # codec-encoded) K/V; the index shares them in place —
                # per-(page, token) encoding keeps a future hit
                # bit-identical to the sharing-off run
                pool.register_prefix(slot)
                self._record_first_token(req, slot.tok)
                m.record_admit(1, 0.0, tokens=1)
                # the install copy the gathered oracle performs at the
                # end of every prefill never happened here
                m.record_prefill_gather(0, pool.install_bytes)
                self._maybe_finish(pool, slot, completed)
        if active:
            m.record_decode_step(emitted, dt_decode,
                                 n_slots=pool.n_slots)
            m.record_pages(pool.pages_in_use(), pool.allocator.total)
            if pool.prefix is not None:
                m.record_shared_pages(pool.allocator.shared_pages())
            m.record_kv_gather(0, pool.gather_bytes_avoided_per_step)
            if pool.codec:
                m.record_kv_codec(pool.pages_in_use() * pool.page_bytes_fp,
                                  pool.pages_in_use() *
                                  pool.page_bytes_resident)
            if self.log_every and m.decode_steps % self.log_every == 0:
                self.emit(self.engine.stats_line())

    def _propose_drafts(self, pool: SlotPool, active: list[Slot],
                        cap: int | None = None) -> dict[int, np.ndarray]:
        """Ask the drafter for up to ``draft_k`` guesses per active slot
        -> {slot.index: draft tokens}.  Per-slot limits keep every
        accepted run inside the request's token budget (``remaining - 1``
        — the verified bonus token always fits) and the slot's cache
        (writes stop at ``slot_len - 1``); ``cap`` adds a backend bound
        (rolling-lane snapshot depth on the mixed path)."""
        hists = [np.concatenate([np.asarray(s.req.prompt, np.int64),
                                 np.asarray(s.req.generated, np.int64)])
                 for s in active]
        limits = []
        for s in active:
            lim = s.req.max_new_tokens - len(s.req.generated) - 1
            lim = min(lim, pool.slot_len - 1 - s.pos)
            if cap is not None:
                lim = min(lim, cap)
            limits.append(max(lim, 0))
        drafts = self.drafter.propose(hists, self.draft_k, limits=limits)
        return {s.index: np.asarray(d, np.int64)
                for s, d in zip(active, drafts)}

    def _spec_step(self, pool: SlotPool, completed: list[Request]) -> None:
        """One speculative round on the gathered / monolithic backends:
        draft -> one ragged scoring pass over every slot lane (phase 1,
        cache discarded) -> greedy accept on the host -> one committing
        pass at the accepted lengths (phase 2, cache donated).  Rejected
        drafts never touch the resident cache, so rollback is free by
        construction; greedy acceptance emits exactly the argmax chain
        plain decoding would, so the output is token-identical."""
        m = self.engine.metrics
        tel = self.engine.telemetry
        active = pool.active()
        t0 = time.monotonic()
        with tel.timed("spec_draft"):
            drafts = self._propose_drafts(pool, active)
        if not any(len(d) for d in drafts.values()):
            # nothing proposed anywhere: a plain decode step is cheaper
            # than a two-phase verify round at Q = 1
            with tel.timed("decode"):
                self._step(pool, completed)
            return
        qn = 1 + self.draft_k
        toks = np.zeros((pool.n_slots, 1, qn), np.int32)
        poss = np.zeros(pool.n_slots, np.int32)
        q_lens = np.zeros(pool.n_slots, np.int32)
        for s in active:
            d = drafts[s.index]
            toks[s.index, 0, 0] = s.tok
            if len(d):
                toks[s.index, 0, 1:1 + len(d)] = d
            poss[s.index] = s.pos
            q_lens[s.index] = 1 + len(d)
            if pool.paged:
                # the real token and every draft write [pos, pos + d]:
                # shared pages under the range go copy-on-write first
                pool._prepare_write(s, s.pos, s.pos + len(d))
                pool._ensure_pages(s, s.pos + len(d))
        params = self.engine.step_params()
        jtoks, jposs = jnp.asarray(toks), jnp.asarray(poss)
        with tel.timed("spec_verify"):
            logits, ctx = pool.spec_score(params, jtoks, jposs, q_lens)
            g = np.asarray(jnp.argmax(logits[:, 0], axis=-1))     # (S, Q)
            finite = np.asarray(jnp.isfinite(logits[:, 0]).all(axis=-1))
        accepted: dict[int, int] = {}
        commit_lens = np.zeros(pool.n_slots, np.int32)
        for s in active:
            d = drafts[s.index]
            a = 0
            while a < len(d) and int(d[a]) == int(g[s.index, a]):
                a += 1
            accepted[s.index] = a
            commit_lens[s.index] = 1 + a
        with tel.timed("spec_rollback"):
            pool.spec_commit(params, jtoks, jposs, commit_lens, ctx)
        dt = time.monotonic() - t0
        emitted = 0
        for s in active:
            a = accepted[s.index]
            if not finite[s.index, :a + 1].all():
                raise RuntimeError(
                    f"non-finite logits in speculative step for request "
                    f"{s.req.rid} (compressed reconstruction or model "
                    f"numerics are broken)")
            for t in g[s.index, :a + 1]:
                s.req.generated.append(int(t))
            emitted += a + 1
            s.pos += a + 1
            s.tok = int(g[s.index, a])
            m.record_spec(len(drafts[s.index]), a)
            self._maybe_finish(pool, s, completed)
        m.record_decode_step(emitted, dt, n_slots=pool.n_slots)
        m.record_pages(pool.pages_in_use(),
                       pool.allocator.total if pool.paged else 0)
        if pool.prefix is not None:
            m.record_shared_pages(pool.allocator.shared_pages())
        m.record_kv_gather(pool.gather_bytes_per_step,
                           pool.gather_bytes_avoided_per_step)
        if pool.codec:
            m.record_kv_codec(pool.pages_in_use() * pool.page_bytes_fp,
                              pool.pages_in_use() *
                              pool.page_bytes_resident)
        if self.log_every and m.decode_steps % self.log_every == 0:
            self.emit(self.engine.stats_line())

    def _step(self, pool: SlotPool, completed: list[Request]) -> None:
        m = self.engine.metrics
        t0 = time.monotonic()
        params = self.engine.step_params()
        results = pool.decode(params)
        n_active = len(results)
        for slot, tok, finite in results:
            if not finite:
                raise RuntimeError(
                    f"non-finite logits in decode step for request "
                    f"{slot.req.rid} (compressed reconstruction or model "
                    f"numerics are broken)")
            slot.req.generated.append(tok)
            self._maybe_finish(pool, slot, completed)
        m.record_decode_step(n_active, time.monotonic() - t0,
                             n_slots=pool.n_slots)
        m.record_pages(pool.pages_in_use(),
                       pool.allocator.total if pool.paged else 0)
        if pool.prefix is not None:
            m.record_shared_pages(pool.allocator.shared_pages())
        m.record_kv_gather(pool.gather_bytes_per_step,
                          pool.gather_bytes_avoided_per_step)
        if pool.codec:
            m.record_kv_codec(pool.pages_in_use() * pool.page_bytes_fp,
                              pool.pages_in_use() *
                              pool.page_bytes_resident)
        if self.log_every and m.decode_steps % self.log_every == 0:
            self.emit(self.engine.stats_line())
