"""Roofline table builder: reads the dry-run artifacts and emits the
three-term analysis per (arch x shape x mesh).

Terms (seconds/step/device), hardware: TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (2 links engaged per axis assumed):

  compute    = census_FLOPs / 197e12
  memory     = census_HBM_bytes / 819e9
  collective = census_collective_bytes / (2 * 50e9)

census_* are trip-weighted per-device statics from launch.hlo_census (XLA's
cost_analysis undercounts scan bodies; see that module).  The memory term
is an upper bound at CPU-backend fusion granularity.
"""

from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
ICI = 2 * 50e9

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "dryrun")


def load_cells(pattern: str = "*.json") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyse(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    comp = cell["flops"] / PEAK
    mem = cell["bytes_accessed"] / HBM
    coll = cell["collectives"]["total"] / ICI
    dominant = max(("compute", comp), ("memory", mem),
                   ("collective", coll), key=lambda kv: kv[1])
    useful = cell["model_flops"] / max(cell["flops"] * cell["devices"], 1)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant[0],
        "roofline_fraction": dominant[1] and comp / max(
            comp, mem, coll),
        "useful_flops_ratio": useful,
        "model_flops": cell["model_flops"],
        "hlo_flops_global": cell["flops"] * cell["devices"],
    }


def run(mesh: str = "single") -> list[str]:
    rows = ["arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
            "roofline_frac,useful_ratio"]
    for cell in load_cells(f"*__{mesh}.json"):
        if cell.get("status", "").startswith("skip"):
            rows.append(f"{cell['arch']},{cell['shape']},{mesh},,,,"
                        f"{cell['status']},,")
            continue
        a = analyse(cell)
        if a is None:
            rows.append(f"{cell['arch']},{cell['shape']},{mesh},,,,"
                        f"FAILED,,")
            continue
        rows.append(
            f"{a['arch']},{a['shape']},{mesh},{a['compute_s']:.3f},"
            f"{a['memory_s']:.3f},{a['collective_s']:.3f},{a['dominant']},"
            f"{a['roofline_fraction']:.3f},{a['useful_flops_ratio']:.3f}")
    return rows
