"""Paper §VI performance claims, adapted to TPU (claim C4).

The paper reports: software-only decode = 1.47x SLOWDOWN; hardware decode
unit = 1.35x speedup (loads overlap compute).  The TPU analogue measured
here, per (Cout, Cin) conv-as-GEMM workload:

  * weight HBM bytes: uncompressed packed words vs tiled compressed words
    -> the memory-roofline reduction of the weight-streaming term;
  * decode arithmetic: VPU op count of the fused kernel's decode stage vs
    the contraction stage (shows decode "fits in the shadow" of compute,
    the overlap argument) for both gather strategies;
  * CPU wall-clock of the jnp reference paths, reproducing the paper's
    software-only slowdown qualitatively.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, compression, frequency
from repro.kernels import ops, ref

HBM_GBPS = 819.0
PEAK_TFLOPS = 197.0


def _weights(rng, cout, cin):
    hist = frequency.synthetic_histogram((0.65, 0.25, 0.08, 0.006),
                                         cout * cin, rng)
    vals = np.repeat(np.arange(512), hist)[: cout * cin]
    rng.shuffle(vals)
    return bitpack.sequences_to_kernel(
        vals.reshape(cout, cin).astype(np.uint16))


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> list[str]:
    rng = np.random.default_rng(2)
    rows = ["layer,weight_bytes_packed,weight_bytes_compressed,"
            "hbm_reduction,decode_vpu_ops,contract_vpu_ops,decode_share"]
    for cout, cin, m in [(64, 64, 1024), (128, 128, 1024),
                         (256, 256, 4096)]:
        w = _weights(rng, cout, cin)
        packed_bytes = cout * (cin // 32) * 9 * 4
        fc = compression.compress_gemm_fused(
            w.reshape(cout, cin * 9), cluster=True)
        comp_bytes = fc.words.size * 4
        # vectorised-op model of the fused kernel (per weight tile of 1024
        # sequences): decode = C steps x (W-row select + 5-row bitplane LUT
        # + arith ~ 40 vec-ops); contraction = bm x (32x9 xnor+pc+acc)/128
        w_rows = fc.words.shape[2]
        decode_ops = 8 * (w_rows * 2 + 5 * 9 + 40)
        bm = min(m, 256)
        contract_ops = bm * 32 * 9 * 3 // 128
        share = decode_ops / max(contract_ops, 1)
        rows.append(
            f"conv{cout}x{cin},{packed_bytes},{comp_bytes},"
            f"{packed_bytes / comp_bytes:.3f},{decode_ops},{contract_ops},"
            f"{share:.2f}")

    # CPU wall clock, paper's software-decode slowdown analogue:
    # uncompressed packed GEMM vs decode-then-GEMM in pure jnp
    cout, cin, m = 64, 64, 512
    w = _weights(rng, cout, cin).astype(np.float32) * 2 - 1
    x = rng.standard_normal((m, cin * 9)).astype(np.float32)
    xw = ref.binarize_pack(jnp.asarray(x))
    ww = ref.binarize_pack(jnp.asarray(w.reshape(cout, -1)))
    fc = compression.compress_gemm_fused(
        (w.reshape(cout, -1) >= 0).astype(np.uint8), cluster=False)
    words = jnp.asarray(fc.words.reshape(-1, fc.words.shape[2],
                                         128))
    tables = jnp.asarray(fc.ct.decode_tables())

    base = jax.jit(lambda a, b: ref.popcount_dot(a, b, cin * 9))
    t_base = _time(base, xw, ww)

    nb, gb = fc.words.shape[:2]

    def sw_decode_then_dot(a, wd):
        dec = ref.decode_tiled(wd, tables, 8)           # software decode
        seqs = dec.reshape(nb, gb, 8 * 128)[..., :1024]
        seqs = seqs.reshape(nb, gb, 32, 32).swapaxes(1, 2) \
            .reshape(nb * 32, gb * 32)[:cout]
        wwd = ref.pack_sequences(seqs)
        return ref.popcount_dot(a, wwd, cin * 9)

    sw = jax.jit(sw_decode_then_dot)
    t_sw = _time(sw, xw, words)
    rows.append(f"# software-decode GEMM slowdown (CPU wall): "
                f"{t_sw / t_base:.2f}x (paper software-only: 1.47x)")
    rows.append(f"# weight-stream memory-term reduction (clustered): "
                f"{rows[1].split(',')[3]}x -> projected decode-bound "
                "speedup on weight-streaming-bound layers")
    return rows
