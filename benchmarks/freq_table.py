"""Paper Table II / Fig. 3 reproduction: bit-sequence frequency analysis.

Two weight sources (DESIGN.md §7.1 — ImageNet is unavailable offline):
  * a tiny ReActNet trained on the synthetic image task until the binary
    kernels develop structure;
  * frequency-matched synthetic kernels drawn from the paper's published
    node marginals.

Claim C1 checked: the distribution is skewed — top-64 share far above the
uniform 12.5%, all-zeros/ones prominent.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, frequency
from repro.data.pipeline import SyntheticImages
from repro.models import reactnet as rn
from repro.train import optimizer as opt


def train_tiny_reactnet(steps: int = 60, seed: int = 0):
    cfg = dataclasses.replace(
        rn.CONFIG, width=32, num_classes=10, image_size=32,
        blocks=((2, 1), (1, 2), (2, 2), (1, 1)))
    params = rn.init_params(cfg, jax.random.PRNGKey(seed))
    oc = opt.OptConfig(lr=2e-2, warmup_steps=5, total_steps=steps,
                       weight_decay=1e-4, clip_latent=1.5)
    state = opt.init_state(params)
    data = SyntheticImages(10, 32, 32)

    @jax.jit
    def step_fn(params, state, images, labels):
        loss, grads = jax.value_and_grad(
            lambda p: rn.loss_fn(cfg, p, {"images": images,
                                          "labels": labels}))(params)
        params, state, _ = opt.apply_updates(params, grads, state, oc)
        return params, state, loss

    first = last = None
    for i in range(steps):
        b = data.batch(i)
        params, state, loss = step_fn(params, state,
                                      jnp.asarray(b["images"]),
                                      jnp.asarray(b["labels"]))
        if i == 0:
            first = float(loss)
    last = float(loss)
    return cfg, params, first, last


def run() -> list[str]:
    rows = ["source,block,top16,top64,top256,zeros_ones,uniform_top64"]
    cfg, params, first, last = train_tiny_reactnet()
    bits = rn.binary_weight_bits(params)
    for i, (name, w) in enumerate(sorted(bits.items())):
        if not name.endswith("w3"):
            continue
        hist = frequency.sequence_histogram(bitpack.kernel_to_sequences(w))
        s = frequency.BlockStats.from_hist(i, hist)
        rows.append(f"trained-tiny,{name},{s.top16:.3f},{s.top64:.3f},"
                    f"{s.top256:.3f},{s.all_zero_one:.3f},0.125")
    rng = np.random.default_rng(0)
    for blk in range(3):
        hist = frequency.synthetic_histogram(
            (0.46, 0.24, 0.23, 0.05), 200_000, rng)
        s = frequency.BlockStats.from_hist(blk, hist)
        rows.append(f"paper-marginals,block{blk},{s.top16:.3f},"
                    f"{s.top64:.3f},{s.top256:.3f},{s.all_zero_one:.3f},"
                    "0.125")
    rows.append(f"# tiny-reactnet train loss {first:.3f} -> {last:.3f}")
    return rows
