"""Paged-attention kernel microbenchmarks: PR-8 launch shape vs tuned.

Three sections, all emitted into one JSON report (``--out``):

1. **Kernel sweep**: the paged mixed-attention kernel timed directly on
   synthetic pools, ``pr8`` launch shape (unpadded pool, one page DMA
   per grid step, one-hot codec dequant) vs ``tuned`` (pool rows padded
   to the 8-sublane tile, ``pages_per_step >= 2`` so the next grid
   step's page DMAs overlap this step's compute, gathered codebook
   lookup).  Swept over page size x decode/mixed Q x codec.  Outputs
   are asserted numerically equivalent between variants (bit-identical
   when only the layout padding differs; allclose when the page-group
   size regroups the online softmax).

2. **Autotune**: ``runtime.autotune.tune_kernel`` sweeping
   ``(q_block, pages_per_step)`` on a reduced minitron-8b geometry —
   the winner the serve path picks up under ``--kernel-tune auto`` —
   plus its memoisation key.

3. **Serve identity**: the same request mix served end-to-end under
   the gathered oracle, the PR-8 kernel launch (``kernel_tune="off"``)
   and the tuned launch (``kernel_tune="0,2"``), under both KV codecs.
   Tokens must be identical within each codec — the tiling padding,
   multi-page DMAs and gather dequant are layout/engine changes, not
   numerics changes.

On hosts without a TPU the kernel runs through the Pallas interpreter
(same convention as the test suite): timings then compare the work each
launch shape *performs*, not TPU-compiled speed — the one-hot dequant's
O(page x 256) expansion and the per-grid-step overhead are both real in
either mode.

Run:  PYTHONPATH=src python benchmarks/kernel_bench.py
      PYTHONPATH=src python benchmarks/kernel_bench.py --smoke \
          --out BENCH_kernels.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

TILE_SUBLANE = 8   # f32 sublane tile: pool page rows pad to this


def _round_up(n: int, tile: int) -> int:
    return -(-n // tile) * tile


# ---------------------------------------------------------------------------
# synthetic pools (same layout the SlotPool builds)
# ---------------------------------------------------------------------------

def make_case(rng, *, n_slots, pages_per_slot, page, kh, d, dv, q, h,
              codec, padded):
    """Pools + table + queries for one kernel launch.

    ``padded`` pads the page row dim to the sublane tile (feature dims
    here are chosen lane-aligned already, as real head dims are); codec
    pools hold int8 codes + per-(page, token) f32 scales, zero-padded
    rows decoding to exactly 0 by the codebook's ZERO_CODE convention.
    """
    from repro.kernels.kv_codec import MAX_CODE, codebook

    rows = _round_up(page, TILE_SUBLANE) if padded else page
    n_pages = n_slots * pages_per_slot + 1          # page 0 = dummy
    table = np.zeros((n_slots, pages_per_slot), np.int32)
    table.flat[:] = rng.permutation(n_pages - 1)[:table.size] + 1
    lengths = np.full((n_slots,), pages_per_slot * page, np.int32)
    q_arr = rng.standard_normal((n_slots, q, h, d)).astype(np.float32)
    q_lens = np.full((n_slots,), q, np.int32)

    def pool(feat):
        live = rng.standard_normal(
            (n_pages, page, kh, feat)).astype(np.float32)
        out = np.zeros((n_pages, rows, kh, feat), np.float32)
        out[:, :page] = live
        return out

    case = dict(q=q_arr, table=table, lengths=lengths, q_lens=q_lens,
                page_size=page if padded else 0)
    if not codec:
        case.update(k_pages=pool(d), v_pages=pool(dv))
        return case
    cb = np.asarray(codebook())

    def codes():
        out = np.zeros((n_pages, rows, kh, d), np.int8)
        out[:, :page] = rng.integers(
            -MAX_CODE, MAX_CODE + 1, (n_pages, page, kh, d), dtype=np.int64)
        return out

    def scales():
        out = np.zeros((n_pages, rows), np.float32)
        out[:, :page] = rng.uniform(0.5, 2.0, (n_pages, page))
        return out

    case.update(k_pages=codes(), v_pages=codes(), k_scales=scales(),
                v_scales=scales(), codebook=cb)
    return case


def run_case(case, *, pps, dequant, q_block, interpret):
    import jax

    from repro.kernels.paged_attention import paged_mixed_attention

    return jax.block_until_ready(paged_mixed_attention(
        case["q"], case["k_pages"], case["v_pages"], case["table"],
        case["lengths"], case["q_lens"],
        k_scales=case.get("k_scales"), v_scales=case.get("v_scales"),
        codebook=case.get("codebook"), page_size=case["page_size"],
        pages_per_step=pps, dequant=dequant, q_block=q_block,
        interpret=interpret))


def bench(fn, repeats):
    fn()                                            # warmup + compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1000.0


# ---------------------------------------------------------------------------
# section 1: kernel sweep, pr8 launch shape vs tuned
# ---------------------------------------------------------------------------

VARIANTS = {
    # PR-8 launch: physical page per grid step, no layout padding,
    # one-hot codec dequant (codes x (page, LEVELS) masked matmul)
    "pr8": dict(padded=False, pps=1, dequant="onehot"),
    # hardware-shaped launch: sublane-padded pool rows, two page DMAs
    # per grid step (double buffering), gathered codebook lookup
    "tuned": dict(padded=True, pps=2, dequant="gather"),
}


def kernel_sweep(smoke: bool, seed: int, repeats: int) -> list:
    import jax

    interpret = jax.default_backend() != "tpu"
    pages = (8,) if smoke else (4, 8, 16)
    qs = (1,) if smoke else (1, 8)
    pages_per_slot = 4 if smoke else 8
    kh, h, d, dv = 2, 4, 128, 128                   # lane-aligned dims
    print(f"kernel sweep: {len(pages)} page sizes x Q {qs} x codec "
          f"{{fp,cluster}}, 4 slots x {pages_per_slot} pages, "
          f"kh={kh} h={h} d={d} "
          f"({'interpreted' if interpret else 'TPU-compiled'})")
    print(f"{'codec':>8} {'page':>5} {'Q':>3} | {'pr8 ms':>8} | "
          f"{'tuned ms':>8} | {'speedup':>7}")
    rows = []
    for codec in (False, True):
        for page in pages:
            for q in qs:
                outs = {}
                row = dict(codec="cluster" if codec else "none",
                           page=page, q=q)
                for label, v in VARIANTS.items():
                    # identical draws per variant: only the layout differs
                    rng = np.random.default_rng(seed)
                    case = make_case(
                        rng, n_slots=4, pages_per_slot=pages_per_slot,
                        page=page, kh=kh, d=d, dv=dv, q=q, h=h,
                        codec=codec, padded=v["padded"])
                    kw = dict(pps=v["pps"], dequant=v["dequant"],
                              q_block=0, interpret=interpret)
                    outs[label] = run_case(case, **kw)
                    row[f"{label}_ms"] = bench(
                        lambda case=case, kw=kw: run_case(case, **kw),
                        repeats)
                # layout + dequant changes must not change the math;
                # pps regroups the online softmax, hence allclose
                np.testing.assert_allclose(
                    outs["tuned"], outs["pr8"], rtol=2e-6, atol=2e-6)
                row["speedup"] = row["pr8_ms"] / row["tuned_ms"]
                rows.append(row)
                print(f"{row['codec']:>8} {page:>5} {q:>3} | "
                      f"{row['pr8_ms']:>8.2f} | {row['tuned_ms']:>8.2f} | "
                      f"{row['speedup']:>6.2f}x")
    return rows


# ---------------------------------------------------------------------------
# section 2: the autotuner's pick on a reduced serving geometry
# ---------------------------------------------------------------------------

def autotune_report(smoke: bool) -> dict:
    import jax

    from repro.configs.base import get_config
    from repro.runtime.autotune import tune_kernel

    cfg = get_config("minitron-8b").scaled(
        dtype="float32", vocab_size=128, num_layers=2, scan_repeats=2,
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)
    interpret = jax.default_backend() != "tpu"
    picks = {}
    for q in (1,) if smoke else (1, 8):
        r = tune_kernel(cfg, 8, q, codec=True, interpret=interpret,
                        repeats=1 if smoke else 3)
        picks[f"Q={q}"] = {k: r[k] for k in
                           ("q_block", "pages_per_step", "best_ms")}
        print(f"autotune minitron-8b page=8 Q={q}: q_block={r['q_block']} "
              f"pages_per_step={r['pages_per_step']} "
              f"({r['best_ms']:.2f} ms best of {len(r['timings'])})")
    return picks


# ---------------------------------------------------------------------------
# section 3: end-to-end token identity, oracle vs pr8 vs tuned launches
# ---------------------------------------------------------------------------

def serve_identity(smoke: bool, seed: int) -> dict:
    import jax

    from repro.configs.base import get_config
    from repro.models.api import get_model
    from repro.runtime import Scheduler, ServeEngine

    cfg = get_config("minitron-8b").scaled(
        dtype="float32", vocab_size=128, num_layers=2, scan_repeats=2,
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)
    params = jax.tree_util.tree_map(
        np.asarray, get_model(cfg).init_params(cfg, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(seed)
    n = 4 if smoke else 8
    reqs = [(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16))),
             int(rng.integers(3, 9))) for _ in range(n)]
    slot_len = max(len(p) + g for p, g in reqs)
    launches = {
        "oracle": dict(attn_backend="gathered"),
        "pr8": dict(attn_backend="pallas_paged", kernel_tune="off"),
        "tuned": dict(attn_backend="pallas_paged", kernel_tune="0,2"),
    }
    print(f"\nserve identity: {n} requests, page size 4, both codecs, "
          f"launches {list(launches)}")
    report = {}
    for kv_codec in ("none", "cluster"):
        # chunked prefill exercises the mixed-step path; the gathered
        # backend's chunked install now quantises rows through the codec
        # before attention (same fixed point the in-pool mixed-step
        # write reaches), so the cross-backend oracle runs chunked under
        # both codecs
        chunk = dict(prefill_chunk=4)
        toks = {}
        for label, kw in launches.items():
            engine = ServeEngine(cfg, params, compress=True)
            sched = Scheduler(engine, batch_size=2, slot_len=slot_len,
                              buckets=(32,), kv_page_size=4,
                              kv_codec=kv_codec, **chunk, **kw)
            for prompt, gen in reqs:
                sched.submit(prompt, gen)
            done = sched.run()
            assert len(done) == n
            toks[label] = [list(map(int, r.generated)) for r in
                           sorted(done, key=lambda r: r.rid)]
        for label in ("pr8", "tuned"):
            assert toks[label] == toks["oracle"], (
                f"kv_codec={kv_codec}: {label} launch changed tokens "
                f"vs the gathered oracle")
        print(f"  kv_codec={kv_codec}: pr8 == tuned == gathered oracle "
              f"({sum(len(t) for t in toks['oracle'])} tokens)")
        report[kv_codec] = dict(identical=True, tokens=toks["oracle"])
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid + repeats for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per variant (default 5, smoke 2)")
    ap.add_argument("--out", type=str, default=None,
                    help="write the JSON report (e.g. BENCH_kernels.json)")
    args = ap.parse_args()
    repeats = args.repeats or (2 if args.smoke else 5)

    rows = kernel_sweep(args.smoke, args.seed, repeats)
    picks = autotune_report(args.smoke)
    identity = serve_identity(args.smoke, args.seed)

    best = max((r for r in rows if r["page"] >= 8),
               key=lambda r: r["speedup"])
    print(f"\nbest speedup at page >= 8: {best['speedup']:.2f}x "
          f"(codec={best['codec']}, page={best['page']}, Q={best['q']})")
    if not args.smoke:
        # the PR's acceptance bar; skipped in --smoke where the tiny
        # grid + CI-runner jitter make timing ratios unreliable
        assert best["speedup"] >= 1.15, \
            f"tuned kernel speedup {best['speedup']:.2f}x < 1.15x"

    if args.out:
        report = dict(
            generated_by="benchmarks/kernel_bench.py",
            smoke=args.smoke, seed=args.seed, repeats=repeats,
            variants={k: dict(v) for k, v in VARIANTS.items()},
            kernel_sweep=rows, autotune=picks,
            serve_identity={k: v["identical"] for k, v in identity.items()},
            best_speedup_page_ge8=best["speedup"])
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
