"""Benchmark orchestrator — one section per paper table/figure + roofline.

Prints ``name,...`` CSV sections.  Usage:
    PYTHONPATH=src python -m benchmarks.run [section ...]
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (ablation_nodes, compression_table, freq_table,
                            roofline, speedup)

    sections = {
        "freq_table": freq_table.run,            # paper Table II / Fig 3
        "compression_table": compression_table.run,   # paper Table V
        "speedup": speedup.run,                  # paper §VI perf claims
        "ablation_nodes": ablation_nodes.run,    # beyond-paper design space
        "roofline_single": lambda: roofline.run("single"),
        "roofline_multi": lambda: roofline.run("multi"),
    }
    want = sys.argv[1:] or list(sections)
    for name in want:
        t0 = time.monotonic()
        print(f"\n== {name} ==")
        try:
            for row in sections[name]():
                print(row)
        except Exception as e:  # noqa: BLE001
            print(f"# FAILED: {type(e).__name__}: {e}")
        print(f"# ({time.monotonic() - t0:.1f}s)")


if __name__ == "__main__":
    main()
