"""Beyond-paper ablations of the simplified-tree design space.

1. Node-capacity ablation — the paper fixes 4 nodes at 32/64/64/256 with
   code lengths 6/8/9/12 and reports it as "a good trade-off" without
   data.  Here: expected bits/sequence for alternative node layouts on the
   same histograms (trained tiny-ReActNet + paper-marginal synthetic),
   against the full-Huffman bound.  A layout is (capacities, code-length
   per node); the last node is always the raw-9-bit escape.

2. Clustering (M, N) search — the paper: "we empirically searched for some
   combinations of M and N".  Reproduced as a grid: ratio after replacing
   the N least-common sequences into the top-M set.
"""

from __future__ import annotations

import numpy as np

from repro.core import bitpack, clustering, frequency, huffman
from repro.models import reactnet as rn

# (name, capacities-of-table-nodes, code lengths incl. 12-bit escape)
LAYOUTS = [
    ("paper 32/64/64+esc", (32, 64, 64), (6, 8, 9, 12)),
    ("2-node 64+esc", (64,), (7, 10)),
    ("2-node 256+esc", (256,), (9, 10)),
    ("3-node 16/64+esc", (16, 64), (5, 8, 11)),
    ("3-node 64/192+esc", (64, 192), (7, 9, 11)),
    ("5-node 16/32/64/128+esc", (16, 32, 64, 128), (5, 7, 9, 10, 13)),
]


def spec_avg_bits(hist: np.ndarray, caps, lens) -> float:
    order = frequency.ranked_sequences(hist)
    total = max(hist.sum(), 1)
    bits = 0.0
    start = 0
    for cap, ln in zip(caps, lens[:-1]):
        seg = order[start:start + cap]
        bits += hist[seg].sum() * ln
        start += cap
    bits += hist[order[start:]].sum() * lens[-1]        # escape node
    return bits / total


def _histograms():
    rng = np.random.default_rng(0)
    hists = {"paper-marginals": frequency.synthetic_histogram(
        (0.46, 0.24, 0.23, 0.05), 200_000, rng)}
    from benchmarks.freq_table import train_tiny_reactnet
    cfg, params, _, _ = train_tiny_reactnet(steps=40)
    agg = np.zeros(512, np.int64)
    for name, w in rn.binary_weight_bits(params).items():
        if name.endswith("w3"):
            agg += frequency.sequence_histogram(
                bitpack.kernel_to_sequences(w))
    hists["trained-tiny-reactnet"] = agg
    return hists


def run() -> list[str]:
    rows = ["source,layout,avg_bits,ratio,vs_full_huffman_bound"]
    for src, hist in _histograms().items():
        bound = huffman.full_huffman_avg_bits(hist)
        for name, caps, lens in LAYOUTS:
            ab = spec_avg_bits(hist, caps, lens)
            rows.append(f"{src},{name},{ab:.3f},{9 / ab:.3f},"
                        f"{bound / ab:.3f}")
        rows.append(f"{src},full-huffman-bound,{bound:.3f},"
                    f"{9 / bound:.3f},1.000")

    # ---- clustering (M, N) grid (paper §III-C empirical search) ----------
    rows.append("")
    rows.append("clustering-grid:M,N,ratio_after_clustering")
    rng = np.random.default_rng(1)
    hist = frequency.synthetic_histogram((0.46, 0.24, 0.23, 0.05),
                                         120_000, rng)
    vals = np.repeat(np.arange(512), hist).astype(np.uint16)
    rng.shuffle(vals)
    for m in (32, 64, 128):
        for n in (64, 128, 256, 448):
            cl, _ = clustering.apply_clustering(vals, m=m, n=n)
            h2 = frequency.sequence_histogram(cl)
            r = huffman.assign_nodes(h2).compression_ratio(h2)
            rows.append(f"{m},{n},{r:.3f}")
    return rows
