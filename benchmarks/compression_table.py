"""Paper Table V reproduction: per-block compression ratio, Encoding vs
Clustering, plus the whole-model ratio (paper: 1.32x kernels / 1.2x model)
and the TPU tiled-layout overhead (DESIGN.md §2)."""

from __future__ import annotations

import numpy as np

from repro.core import bitpack, compression, frequency, huffman


def synthetic_block_weights(rng, cout=64, cin=64,
                            shares=(0.46, 0.24, 0.23, 0.05)):
    hist = frequency.synthetic_histogram(shares, cout * cin, rng)
    vals = np.repeat(np.arange(512), hist)[: cout * cin]
    rng.shuffle(vals)
    return bitpack.sequences_to_kernel(
        vals.reshape(cout, cin).astype(np.uint16))


def run() -> list[str]:
    rng = np.random.default_rng(1)
    rows = ["block,ratio_encoding,ratio_clustering,ratio_tiled_clustering,"
            "full_huffman_bound"]
    enc_all, cl_all = [], []
    tensors = {}
    for blk in range(13):
        w = synthetic_block_weights(rng)
        tensors[f"block{blk}/w3"] = w
        ct_e = compression.compress_conv3x3(w, cluster=False)
        ct_c = compression.compress_conv3x3(w, cluster=True)
        hist = frequency.sequence_histogram(bitpack.kernel_to_sequences(w))
        bound = 9.0 / max(huffman.full_huffman_avg_bits(hist), 1e-9)
        rows.append(f"block{blk + 1},{ct_e.ratio_stream():.3f},"
                    f"{ct_c.ratio_stream():.3f},{ct_c.ratio_tiled():.3f},"
                    f"{bound:.3f}")
        enc_all.append(ct_e.ratio_stream())
        cl_all.append(ct_c.ratio_stream())
    # whole-model figure: binary kernels + the fp remainder of Table I
    # (paper: others+IO layers ~ 32% of bits)
    bin_bits = sum(w.size for w in tensors.values())
    _, rep = compression.compress_model(tensors,
                                        fp_bits=int(bin_bits * 0.47))
    rows.append(f"avg,{np.mean(enc_all):.3f},{np.mean(cl_all):.3f},,")
    rows.append(f"# model ratio {rep.model_ratio:.3f} "
                f"(paper: ~1.2); binary ratio {rep.binary_ratio:.3f} "
                "(paper: 1.32 avg)")
    return rows
