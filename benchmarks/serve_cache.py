"""Decode-tile cache capacity sweep: hit rate vs serving throughput.

The paper's §IV caching unit works because its capacity covers the hot set
of decoded sequences.  The serving-runtime analogue has the same cliff:
during batched decoding every step touches every tile of every compressed
layer (a cyclic scan), so an LRU cache smaller than the decoded working set
thrashes to ~0% hit rate, while one that covers it converges to
(steps-1)/steps.  This sweep measures that cliff and the throughput /
HBM-traffic consequences, per cache capacity:

  capacity (frac of working set) | hit rate | reconstructions/s | bytes streamed

Run:  PYTHONPATH=src python benchmarks/serve_cache.py [--steps 24]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.runtime import DecodeTileCache, WeightStore

LAYERS = 4
D, F = 288, 512
FRACTIONS = (0.1, 0.25, 0.5, 0.75, 1.0, 1.2)


def build_store(cache: DecodeTileCache, rng) -> WeightStore:
    """A stack of motif-structured binary MLP layers (C1-style skew)."""
    params = {}
    for i in range(LAYERS):
        motifs = rng.standard_normal((4, D)).astype(np.float32)
        base = motifs[rng.integers(0, 4, F)] * \
            rng.choice([-1.0, 1.0], F)[:, None]
        base += 0.08 * np.abs(base).mean() * rng.standard_normal((F, D))
        params[f"layer{i}"] = {"mlp": {"up": base.T.astype(np.float32)}}
    store = WeightStore(cache)
    store.register_model("bench", params,
                         select=lambda p, nd: p.endswith("mlp/up"))
    return store


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    # working-set size from an unbounded dry run
    probe = build_store(DecodeTileCache(), rng)
    working_set = probe.decoded_bytes("bench")
    n_tiles = probe.n_tiles("bench")
    print(f"{LAYERS} layers x ({F}x{D}), {n_tiles} decode tiles, "
          f"decoded working set {working_set / 1024:.0f} KiB, "
          f"{args.steps} decode steps\n")
    print(f"{'capacity':>10} {'frac':>5} | {'hit rate':>8} | "
          f"{'recon/s':>8} | {'streamed':>10} | {'evict':>6}")

    for frac in FRACTIONS:
        rng = np.random.default_rng(0)          # identical weights per run
        cache = DecodeTileCache(int(working_set * frac))
        store = build_store(cache, rng)
        t0 = time.monotonic()
        for _ in range(args.steps):             # one materialise per step
            store.materialize("bench")
        dt = time.monotonic() - t0
        st = cache.stats()
        recon_s = args.steps * LAYERS / dt
        print(f"{cache.capacity_bytes:>10} {frac:>5.2f} | "
              f"{st['hit_rate'] * 100:>7.1f}% | {recon_s:>8.1f} | "
              f"{st['bytes_streamed']:>10} | {st['evictions']:>6}")


if __name__ == "__main__":
    main()
