"""Decode-tile cache benchmarks: capacity sweep + trace replay + slot batching.

Ten sections:

1. **Capacity sweep** (default): the paper's §IV cache cliff on a real
   WeightStore — during batched decoding every step touches every tile of
   every compressed layer (a cyclic scan), so an LRU cache smaller than the
   decoded working set thrashes to ~0% hit rate while one that covers it
   converges to (steps-1)/steps.

2. **Trace replay** (``--trace bursty``): a synthetic multi-tenant serving
   trace with bursty arrivals and Zipf-skewed tenant popularity (the
   serving-time analogue of the paper's §III-A sequence skew), replayed
   through :class:`DecodeTileCache` under every eviction policy at several
   capacities.  One hot tenant dominates accesses while cold tenants burst
   in and out; their full-model tile scans flush recency-based caches but
   not the FrequencyWeighted policy, whose victims are ranked by a prior
   seeded from the tenants' occurrence weights (the role
   ``core.frequency`` histograms play in the real store).

3. **Slot batching** (``--trace``/``--smoke``): the same bursty request
   mix served by the real scheduler on a reduced model in ``wave`` vs
   ``continuous`` mode — identical tokens, different occupancy, so
   slot-level admit-on-retire wins tokens/s.

4. **Chunked vs monolithic prefill** (``--trace``/``--smoke``): a mixed
   long/short prompt trace through the real scheduler — monolithic
   admission prefills a whole long prompt while every other lane waits;
   chunked prefill (+ paged KV) interleaves, so short requests' time to
   first token stops scaling with their neighbours' prompt lengths.
   Token-identical by assertion.

5. **Attention backends** (``--trace``/``--smoke``): the same request mix
   decoded under ``attn_backend="gathered"`` (copy each slot's pages into
   a contiguous view per step, two full cache copies) vs
   ``"pallas_paged"`` (the in-kernel paged-attention backend reads the
   page pool in place).  Token-identical by assertion; the table reports
   decode-step latency and the per-step KV bytes each backend moved /
   avoided.

6. **Capacity autotune** (``--autotune``): sweep a fine capacity grid
   over the replayed trace (synthetic, or ``--trace-file``), locate the
   hit-rate cliff, and print a recommended ``decode_cache`` capacity —
   the knee: the smallest capacity past the cliff within a small
   tolerance of the best measured hit rate (shared logic with the
   launcher's ``--cache-mb auto``: ``runtime.autotune.find_knee``).

7. **Telemetry** (``--trace``/``--smoke``): serve a small mix with
   request-lifecycle tracing on and validate the observability surface
   end to end — Chrome-trace JSON loads with admitted == retired spans,
   the Prometheus text parses with monotone counters across scrapes,
   and tokens are identical to a telemetry-off run.  ``--trace-out`` /
   ``--metrics-out`` additionally write (and re-validate) the files,
   which is what the CI smoke job does.

8. **KV page codec** (``--trace``/``--smoke``): the same request mix
   served with ``kv_codec="cluster"`` vs the fp pools under both
   attention backends.  Cluster stores paged K/V leaves as int8
   codebook codes plus a per-(page, token) f32 scale — >= 1.3x fewer
   resident pool bytes at equal page count by assertion — and the
   table reports the effective-capacity multiplier plus how many
   fully-backed slots one fixed HBM budget holds under each codec.

9. **Prefix sharing** (``--trace``/``--smoke``): the checked-in
   multi-tenant shared-prefix trace replayed with ``prefix_share`` off
   vs on — token-identical by assertion, with the accounting identity
   ``chunk_tokens(on) + tokens_reused == chunk_tokens(off)`` pinning
   that every reused token is prefill work the off run actually paid.

10. **Speculative decoding** (``--trace``/``--smoke``): the checked-in
    repetition-heavy trace (``benchmarks/traces/repetition.jsonl``)
    served with ``speculate="ngram"`` vs ``"off"`` across backend/codec
    cells — token-identical by assertion (greedy verification), drafts
    accepted and decode steps strictly reduced everywhere, and >= 1.2x
    tokens/s on the single-phase ``pallas_paged`` cell (asserted on the
    full run at the default seed).

``--out report.json`` dumps every section's headline numbers (tokens/s,
TTFT, hit/acceptance rates, compression multipliers) as one JSON report;
the checked-in ``BENCH_serve.json`` is generated this way and refreshed
by CI as a build artifact.

Real traffic traces: ``--trace-file path.jsonl`` replays a recorded
trace (one JSON object per line: ``arrival_time`` seconds, ``prompt_len``,
``decode_len``, ``tenant``) through the same policy sweep the synthetic
generator uses; tenant popularity for the FrequencyWeighted prior is
estimated from the trace itself.  A tiny sample lives at
``benchmarks/traces/sample.jsonl`` and is replayed by ``--smoke``.

``--seed`` seeds the synthetic trace generators (bursty arrivals and the
request mixes of the scheduler sections), so replays are reproducible
run-to-run and distinct seeds give distinct-but-reproducible traffic.

Run:  PYTHONPATH=src python benchmarks/serve_cache.py [--steps 24]
      PYTHONPATH=src python benchmarks/serve_cache.py --trace bursty
      PYTHONPATH=src python benchmarks/serve_cache.py \
          --trace-file benchmarks/traces/sample.jsonl
      PYTHONPATH=src python benchmarks/serve_cache.py --autotune
      PYTHONPATH=src python benchmarks/serve_cache.py --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import numpy as np

from repro.runtime import DecodeTileCache, WeightStore
from repro.runtime.autotune import DEFAULT_FRACTIONS, find_knee

SAMPLE_TRACE = pathlib.Path(__file__).parent / "traces" / "sample.jsonl"
SHARED_PREFIX_TRACE = (pathlib.Path(__file__).parent / "traces"
                       / "shared_prefix.jsonl")
REPETITION_TRACE = (pathlib.Path(__file__).parent / "traces"
                    / "repetition.jsonl")

# per-section headline numbers, dumped by --out as BENCH_serve.json
REPORT: dict = {}

LAYERS = 4
D, F = 288, 512
FRACTIONS = (0.1, 0.25, 0.5, 0.75, 1.0, 1.2)

TRACE_FRACTIONS = (0.15, 0.25, 0.4, 0.6, 1.0)
SMOKE_FRACTIONS = (0.25, 0.6, 1.0)
POLICY_NAMES = ("lru", "lfu", "freq")


def build_store(cache: DecodeTileCache, rng) -> WeightStore:
    """A stack of motif-structured binary MLP layers (C1-style skew)."""
    params = {}
    for i in range(LAYERS):
        motifs = rng.standard_normal((4, D)).astype(np.float32)
        base = motifs[rng.integers(0, 4, F)] * \
            rng.choice([-1.0, 1.0], F)[:, None]
        base += 0.08 * np.abs(base).mean() * rng.standard_normal((F, D))
        params[f"layer{i}"] = {"mlp": {"up": base.T.astype(np.float32)}}
    store = WeightStore(cache)
    store.register_model("bench", params,
                         select=lambda p, nd: p.endswith("mlp/up"))
    return store


def capacity_sweep(steps: int) -> None:
    rng = np.random.default_rng(0)
    probe = build_store(DecodeTileCache(), rng)
    working_set = probe.decoded_bytes("bench")
    n_tiles = probe.n_tiles("bench")
    print(f"{LAYERS} layers x ({F}x{D}), {n_tiles} decode tiles, "
          f"decoded working set {working_set / 1024:.0f} KiB, "
          f"{steps} decode steps\n")
    print(f"{'capacity':>10} {'frac':>5} | {'hit rate':>8} | "
          f"{'recon/s':>8} | {'streamed':>10} | {'evict':>6}")

    for frac in FRACTIONS:
        rng = np.random.default_rng(0)          # identical weights per run
        cache = DecodeTileCache(int(working_set * frac))
        store = build_store(cache, rng)
        t0 = time.monotonic()
        for _ in range(steps):                  # one materialise per step
            store.materialize("bench")
        dt = time.monotonic() - t0
        st = cache.stats()
        recon_s = steps * LAYERS / dt
        print(f"{cache.capacity_bytes:>10} {frac:>5.2f} | "
              f"{st['hit_rate'] * 100:>7.1f}% | {recon_s:>8.1f} | "
              f"{st['bytes_streamed']:>10} | {st['evictions']:>6}")


# ---------------------------------------------------------------------------
# trace replay: bursty multi-tenant arrivals over a tile universe
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TraceRequest:
    arrival: int        # earliest admission step
    tenant: int
    gen: int            # decode steps (tokens) the request runs for
    prompt_len: int = 8  # prompt tokens (trace-file ingestion records it)


@dataclasses.dataclass
class Trace:
    """Synthetic bursty serving trace over ``n_tenants`` tenant models."""

    requests: list
    n_tenants: int
    tiles_per_tenant: int
    tile_bytes: int
    popularity: np.ndarray      # per-tenant occurrence weight (Zipf)

    @property
    def total_bytes(self) -> int:
        return self.n_tenants * self.tiles_per_tenant * self.tile_bytes


def bursty_trace(rng, *, n_tenants: int = 8, tiles_per_tenant: int = 32,
                 tile_bytes: int = 4096, n_requests: int = 64,
                 burst: int = 4, gen_lo: int = 4, gen_hi: int = 24) -> Trace:
    """Bursty arrivals, Zipf tenant popularity (tenant 0 dominates).

    Requests arrive in bursts of ~``burst``; each picks a tenant from a
    Zipf(1.6) marginal, so one hot tenant carries most decode steps while
    cold tenants scan their whole tile set through the cache in short
    bursts — the access shape that separates frequency-aware eviction from
    recency-based eviction.
    """
    popularity = 1.0 / np.arange(1, n_tenants + 1) ** 1.6
    popularity /= popularity.sum()
    requests = []
    step = 0
    while len(requests) < n_requests:
        for _ in range(1 + rng.integers(0, burst)):
            if len(requests) >= n_requests:
                break
            tenant = int(rng.choice(n_tenants, p=popularity))
            gen = int(rng.integers(gen_lo, gen_hi + 1))
            requests.append(TraceRequest(step, tenant, gen))
        step += int(rng.integers(1, 7))         # gap until the next burst
    return Trace(requests, n_tenants, tiles_per_tenant, tile_bytes,
                 popularity)


def load_trace_file(path, *, time_step: float = 0.05,
                    tiles_per_tenant: int = 32,
                    tile_bytes: int = 4096) -> Trace:
    """Ingest a recorded serving trace (JSONL) into a :class:`Trace`.

    One JSON object per line with keys ``arrival_time`` (seconds from
    trace start), ``prompt_len``, ``decode_len``, and ``tenant`` (any
    hashable label; mapped to dense indices in order of first
    appearance).  ``time_step`` converts wall-clock arrivals into
    scheduler admission steps.  The FrequencyWeighted prior that the
    synthetic generator takes from its Zipf marginal is estimated here
    from the trace's own tenant frequencies — the serving-time stand-in
    for the paper's §III-A occurrence histogram.
    """
    tenants: dict = {}
    reqs = []
    for line in pathlib.Path(path).read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        row = json.loads(line)
        tenant = tenants.setdefault(row["tenant"], len(tenants))
        reqs.append(TraceRequest(
            arrival=int(float(row["arrival_time"]) / time_step),
            tenant=tenant,
            gen=int(row["decode_len"]),
            prompt_len=int(row["prompt_len"])))
    if not reqs:
        raise ValueError(f"empty trace file: {path}")
    counts = np.bincount([r.tenant for r in reqs],
                         minlength=len(tenants)).astype(np.float64)
    return Trace(reqs, len(tenants), tiles_per_tenant, tile_bytes,
                 counts / counts.sum())


def replay(trace: Trace, cache: DecodeTileCache, n_slots: int = 6) -> dict:
    """Serve the trace with continuous slots, touching every tile of a
    request's tenant each decode step (the materialize scan) -> stats."""
    if cache.policy.name == "freq":
        # the occurrence-count prior: tenant popularity is what the
        # compression-time core.frequency histograms encode in the store
        for m in range(trace.n_tenants):
            for t in range(trace.tiles_per_tenant):
                cache.seed_frequency((m, t), float(trace.popularity[m]))
    queue = sorted(trace.requests, key=lambda r: r.arrival)
    pending = list(queue)
    slots: list = [None] * n_slots   # (tenant, steps_left) per busy lane
    step = 0
    while pending or any(slots):
        for i in range(n_slots):     # admit-on-retire
            if slots[i] is None and pending and pending[0].arrival <= step:
                r = pending.pop(0)
                slots[i] = [r.tenant, r.gen]
        for i in range(n_slots):
            if slots[i] is None:
                continue
            tenant, _ = slots[i]
            for t in range(trace.tiles_per_tenant):
                cache.get_or_decode((tenant, t), lambda: True,
                                    nbytes=trace.tile_bytes,
                                    streamed_bytes=trace.tile_bytes)
            slots[i][1] -= 1
            if slots[i][1] <= 0:
                slots[i] = None
        step += 1
    return cache.stats()


def autotune_capacity(trace: Trace, policy: str = "freq",
                      tolerance: float = 0.02) -> int:
    """Sweep a fine capacity grid over ``trace`` and recommend the
    hit-rate-cliff knee.

    The cliff/knee logic is shared with the launcher's ``--cache-mb
    auto`` path (``runtime.autotune.find_knee``): the cliff is the
    largest hit-rate jump between consecutive capacities (the paper's
    §IV working-set threshold appearing at serving time); the knee is
    the smallest capacity at/after it within ``tolerance`` of the best
    measured rate — everything past it buys memory, not hits.  Returns
    the recommended capacity in bytes.
    """
    fractions = DEFAULT_FRACTIONS
    total = trace.total_bytes
    caps, rates = [], []
    print(f"capacity autotune ({policy} policy, "
          f"{len(trace.requests)} requests, "
          f"{total // 1024} KiB tile universe):\n")
    print(f"{'capacity':>10} {'frac':>5} | {'hit rate':>8}")
    for frac in fractions:
        cache = DecodeTileCache(int(total * frac), policy=policy)
        st = replay(trace, cache)
        caps.append(int(total * frac))
        rates.append(st["hit_rate"])
        print(f"{caps[-1]:>10} {frac:>5.2f} | {rates[-1] * 100:>7.1f}%")
    best = max(rates)
    jumps = [rates[i] - rates[i - 1] for i in range(1, len(rates))]
    cliff = int(np.argmax(jumps)) + 1 if jumps else 0
    knee = find_knee(caps, rates, tolerance=tolerance)
    print(f"\ncliff: {caps[cliff]} bytes "
          f"(+{jumps[cliff - 1] * 100:.1f} pts over the previous "
          f"capacity)" if jumps else "\nno cliff detected")
    print(f"recommended decode_cache capacity: {caps[knee]} bytes "
          f"({fractions[knee]:.2f}x of the decoded universe, "
          f"hit rate {rates[knee] * 100:.1f}%, within "
          f"{tolerance * 100:.0f} pts of best {best * 100:.1f}%)")
    return caps[knee]


def trace_replay(smoke: bool, trace: Trace | None = None,
                 label: str = "bursty", seed: int = 0) -> None:
    if trace is None:
        rng = np.random.default_rng(seed)
        trace = bursty_trace(rng, n_requests=24 if smoke else 64)
    fractions = SMOKE_FRACTIONS if smoke else TRACE_FRACTIONS
    total = trace.total_bytes
    hot_share = float(trace.popularity.max())
    print(f"{label} trace: {len(trace.requests)} requests over "
          f"{trace.n_tenants} tenants x {trace.tiles_per_tenant} tiles "
          f"({total // 1024} KiB universe), hot tenant carries "
          f"~{hot_share * 100:.0f}% of arrivals\n")
    print(f"{'capacity':>10} {'frac':>5} | " +
          " | ".join(f"{p:>6}" for p in POLICY_NAMES) + "   hit rate")
    worst = None
    for frac in fractions:
        rates = {}
        for policy in POLICY_NAMES:
            cache = DecodeTileCache(int(total * frac), policy=policy)
            st = replay(trace, cache)
            rates[policy] = st["hit_rate"]
        print(f"{int(total * frac):>10} {frac:>5.2f} | " +
              " | ".join(f"{rates[p] * 100:5.1f}%" for p in POLICY_NAMES))
        margin = rates["freq"] - rates["lru"]
        worst = margin if worst is None else min(worst, margin)
    print(f"\nFrequencyWeighted - LRU hit-rate margin, worst capacity: "
          f"{worst * 100:+.1f} pts")
    REPORT.setdefault("trace_replay", {})[label] = dict(
        requests=len(trace.requests),
        freq_minus_lru_worst_pts=round(worst * 100, 2))
    # the synthetic replay is fully deterministic (seeded trace, no
    # timing), so the paper-skew claim is a hard invariant CI can
    # enforce on the default seed; recorded traces and alternate seeds
    # carry no such guarantee and just report
    if label == "bursty" and seed == 0:
        assert worst >= 0, \
            f"FrequencyWeighted lost to LRU by {-worst * 100:.1f} pts"


# ---------------------------------------------------------------------------
# chunked vs monolithic prefill on a mixed long/short prompt trace
# ---------------------------------------------------------------------------

def _reduced_lm(vocab_size: int = 128):
    import jax
    from repro.configs.base import get_config
    from repro.models.api import get_model

    cfg = get_config("minitron-8b").scaled(
        dtype="float32", vocab_size=vocab_size, num_layers=2,
        scan_repeats=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128)
    params = jax.tree_util.tree_map(
        np.asarray, get_model(cfg).init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, params


def prefill_compare(smoke: bool, seed: int = 0) -> None:
    """Mixed long/short prompts: monolithic batch-1 prefill stalls every
    lane for a whole long prompt, chunked prefill interleaves the chunks
    with decode steps (round-robin across prefilling slots), so short
    requests reach their first token after their own chunks instead of
    queueing behind a long neighbour's full prompt.  Tokens are identical
    by construction; the table shows what changes: time-to-first-token of
    the short class, and decode throughput while prefills are in flight.
    """
    from repro.runtime import Scheduler, ServeEngine

    cfg, params = _reduced_lm()
    long_len, short_len = (48, 6) if smoke else (96, 8)
    gen_s, gen_l = (6, 4) if smoke else (16, 8)
    n_pairs = 3 if smoke else 6
    rng = np.random.default_rng(seed)
    # long, short, short, long, ... — shorts always queue behind a long
    reqs = []
    for _ in range(n_pairs):
        reqs.append((rng.integers(0, cfg.vocab_size, long_len), gen_l))
        reqs.append((rng.integers(0, cfg.vocab_size, short_len), gen_s))
        reqs.append((rng.integers(0, cfg.vocab_size, short_len), gen_s))
    slot_len = max(len(p) + g for p, g in reqs)
    chunk = 8
    print(f"\nchunked vs monolithic prefill: {len(reqs)} requests "
          f"(prompts {short_len}/{long_len} tokens, chunk {chunk}), "
          f"batch 2, reduced minitron-8b")
    print(f"{'prefill':>12} | {'ttft short':>10} | {'ttft long':>10} | "
          f"{'tok/s':>7} | {'stall':>7}")

    results = {}
    for label, kw in (
            ("monolithic", {}),
            ("chunked", dict(prefill_chunk=chunk, prefill_budget=chunk,
                             kv_page_size=16))):
        engine = ServeEngine(cfg, params, compress=True)
        sched = Scheduler(engine, batch_size=2, slot_len=slot_len,
                          buckets=(128,), **kw)
        sched.submit(reqs[0][0], 2)              # warmup: compile prefill,
        sched.submit(reqs[1][0], 2)              # chunks, and decode shapes
        sched.run()
        engine.metrics = type(engine.metrics)()
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        done = sched.run()
        assert len(done) == len(reqs)
        by_rid = sorted(done, key=lambda r: r.rid)[-len(reqs):]
        ttft = {True: [], False: []}
        for r in by_rid:
            ttft[r.prompt_len <= short_len].append(r.first_token_latency())
        m = engine.metrics
        results[label] = (
            np.mean(ttft[True]), np.mean(ttft[False]), m.tokens_per_s(),
            m.decode_stall_s,
            tuple(tuple(r.generated) for r in by_rid))
        t_s, t_l, tps, stall, _ = results[label]
        print(f"{label:>12} | {t_s * 1000:>8.0f}ms | {t_l * 1000:>8.0f}ms | "
              f"{tps:>7.1f} | {stall:>6.2f}s")
    assert results["monolithic"][4] == results["chunked"][4], \
        "chunked prefill changed generated tokens"
    speedup = results["monolithic"][0] / max(results["chunked"][0], 1e-9)
    print(f"  short-request time-to-first-token: {speedup:.1f}x faster "
          f"chunked (token-identical outputs)")
    REPORT["prefill_compare"] = {
        label: dict(ttft_short_ms=round(results[label][0] * 1000, 1),
                    ttft_long_ms=round(results[label][1] * 1000, 1),
                    tok_s=round(results[label][2], 2))
        for label in ("monolithic", "chunked")}
    # deterministic in structure, robust in time: a short prompt's first
    # token needs 1 chunk + its own prefill, not a neighbour's whole
    # long-prompt prefill
    assert results["chunked"][0] < results["monolithic"][0], \
        "chunked prefill did not improve short-request TTFT"


# ---------------------------------------------------------------------------
# attention backends: paged-gather vs in-kernel decode on the real scheduler
# ---------------------------------------------------------------------------

def backend_compare(smoke: bool, seed: int = 0) -> None:
    """Decode-step latency under the attention backends + mixed step.

    ``gathered`` copies every slot's pages into a contiguous lane view and
    scatters them back *each step* — two full cache copies on the decode
    hot path.  ``pallas_paged`` hands the donated page pool + page tables
    to the paged-attention kernel, which walks the table in-kernel: the
    per-step copies disappear (the kv-gather metric must read exactly 0,
    asserted here).  ``mixed`` adds chunked prefill on top of
    ``pallas_paged``: prompt chunks and decode tokens ride one ragged
    batched trace whose K/V writes land straight in the pools, so the
    *prefill*-side gather (the gathered oracle's install copy of every
    freshly prefilled cache) reads exactly 0 too — also asserted.  Tokens
    are identical by assertion; on CPU the kernel runs interpreted, so
    the latency column shows the copy-free data path, not TPU-compiled
    kernel speed.
    """
    from repro.runtime import Scheduler, ServeEngine

    cfg, params = _reduced_lm()
    rng = np.random.default_rng(seed)
    n = 6 if smoke else 12
    reqs = [(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 20))),
             int(rng.integers(4, 12))) for _ in range(n)]
    slot_len = max(len(p) + g for p, g in reqs)
    print(f"\nattention backends: {n} requests, batch 2, page size 8, "
          f"reduced minitron-8b")
    print(f"{'backend':>14} | {'ms/step':>8} | {'kv moved/step':>13} | "
          f"{'kv avoided/step':>15} | {'prefill moved':>13}")

    configs = {
        "gathered": dict(attn_backend="gathered"),
        "pallas_paged": dict(attn_backend="pallas_paged"),
        "mixed": dict(attn_backend="pallas_paged", prefill_chunk=8),
    }
    results = {}
    for label, kw in configs.items():
        engine = ServeEngine(cfg, params, compress=True)
        sched = Scheduler(engine, batch_size=2, slot_len=slot_len,
                          buckets=(32,), kv_page_size=8, **kw)
        sched.submit(reqs[0][0], 2)              # warmup compile
        sched.run()
        engine.metrics = type(engine.metrics)()
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        done = sched.run()
        assert len(done) == n
        m = engine.metrics
        steps = max(m.decode_steps, 1)
        results[label] = (
            m.ms_per_token(), m.kv_gather_bytes, m.kv_gather_bytes_avoided,
            tuple(tuple(r.generated) for r in
                  sorted(done, key=lambda r: r.rid)[-n:]),
            m.kv_prefill_gather_bytes)
        print(f"{label:>14} | {m.ms_per_token():>8.1f} | "
              f"{m.kv_gather_bytes // steps:>13} | "
              f"{m.kv_gather_bytes_avoided // steps:>15} | "
              f"{m.kv_prefill_gather_bytes:>13}")
    assert results["gathered"][3] == results["pallas_paged"][3], \
        "attention backend changed generated tokens"
    assert results["gathered"][3] == results["mixed"][3], \
        "mixed-step execution changed generated tokens"
    assert results["pallas_paged"][1] == 0, \
        "pallas_paged backend copied KV on the decode hot path"
    assert results["pallas_paged"][2] > 0 and results["gathered"][1] > 0
    assert results["mixed"][1] == 0 and results["mixed"][4] == 0, \
        "mixed-step path copied KV on the prefill or decode hot path"
    assert results["gathered"][4] > 0 and results["pallas_paged"][4] > 0, \
        "install-path prefill copies were not accounted"
    print("  pallas_paged moved 0 gather/scatter bytes; mixed-step also "
          "moved 0 prefill install bytes (token-identical outputs)")
    REPORT["backend_compare"] = {
        label: dict(ms_per_step=round(results[label][0], 2),
                    kv_gather_bytes=results[label][1],
                    kv_prefill_gather_bytes=results[label][4])
        for label in configs}


# ---------------------------------------------------------------------------
# kv page codec: compressed pools vs fp pools at equal HBM budget
# ---------------------------------------------------------------------------

def kv_codec_compare(smoke: bool, seed: int = 0) -> None:
    """Resident-KV compression of ``kv_codec="cluster"`` vs the fp pools.

    The cluster codec stores every paged K/V leaf as int8 codebook codes
    plus one f32 scale per (page, token) — decoded in-kernel under
    ``pallas_paged`` (codebook lookup in VMEM after the per-page DMA,
    before the online-softmax score) and at gather under ``gathered``.
    The table reports tokens/s, resident bytes per page, the effective-
    capacity multiplier, and how many slots one fixed HBM budget backs
    under each codec — the serving win: more resident requests per byte.
    Closeness is reported as the mean per-token agreement with the
    bit-exact ``none`` oracle (greedy argmax on a random-weight reduced
    model amplifies the bounded KV reconstruction error into occasional
    token flips; the documented elementwise bound is max scale / 254,
    printed from the metric).
    """
    from repro.runtime import Scheduler, ServeEngine

    cfg, params = _reduced_lm()
    rng = np.random.default_rng(seed)
    n = 6 if smoke else 12
    reqs = [(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 20))),
             int(rng.integers(4, 12))) for _ in range(n)]
    slot_len = max(len(p) + g for p, g in reqs)
    print(f"\nkv page codec: {n} requests, batch 2, page size 8, "
          f"reduced minitron-8b")
    print(f"{'backend/codec':>22} | {'tok/s':>7} | {'B/page':>7} | "
          f"{'capacity':>8} | {'agree':>6}")

    configs = {
        "gathered/none": dict(attn_backend="gathered", kv_codec="none"),
        "gathered/cluster": dict(attn_backend="gathered",
                                 kv_codec="cluster"),
        "pallas_paged/none": dict(attn_backend="pallas_paged",
                                  kv_codec="none"),
        "pallas_paged/cluster": dict(attn_backend="pallas_paged",
                                     kv_codec="cluster"),
    }
    results = {}
    for label, kw in configs.items():
        engine = ServeEngine(cfg, params, compress=True)
        sched = Scheduler(engine, batch_size=2, slot_len=slot_len,
                          buckets=(32,), kv_page_size=8, **kw)
        sched.submit(reqs[0][0], 2)              # warmup compile
        sched.run()
        engine.metrics = type(engine.metrics)()
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        done = sched.run()
        assert len(done) == n
        m = engine.metrics
        pool = sched._pool
        results[label] = dict(
            toks=tuple(tuple(r.generated) for r in
                       sorted(done, key=lambda r: r.rid)[-n:]),
            tok_s=m.tokens_per_s(),
            page_fp=pool.page_bytes_fp,
            page_res=pool.page_bytes_resident,
            pages_per_slot=pool.pages_per_slot,
            avoided=m.kv_bytes_avoided,
            mult=m.kv_capacity_multiplier(),
            err=m.kv_codec_error_bound)
    base = results["gathered/none"]

    def agreement(toks):
        hits = sum(a == b for t, bt in zip(toks, base["toks"])
                   for a, b in zip(t, bt))
        return hits / sum(len(t) for t in base["toks"])

    for label, r in results.items():
        mult = r["page_fp"] / r["page_res"]
        print(f"{label:>22} | {r['tok_s']:>7.1f} | {r['page_res']:>7} | "
              f"{mult:>7.2f}x | {agreement(r['toks']) * 100:>5.0f}%")

    # "none" is the bit-exact oracle under both backends (PR-5 seam)
    assert results["pallas_paged/none"]["toks"] == base["toks"], \
        "kv_codec='none' is not bit-identical across backends"
    for label in ("gathered/cluster", "pallas_paged/cluster"):
        r = results[label]
        # the at-rest claim: >= 1.3x fewer resident pool bytes at equal
        # page count (int8 + one f32 scale per token vs f32 pages)
        assert r["page_fp"] / r["page_res"] >= 1.3, \
            f"{label}: page compression below 1.3x"
        assert r["avoided"] > 0 and r["mult"] >= 1.3
        assert 0.0 < r["err"] < 0.1, f"{label}: error bound {r['err']}"
        # token closeness vs the oracle: monolithic prefill is exact, so
        # every request's *first* decoded token matches; later tokens
        # drift only within the bounded reconstruction error
        firsts = [t[0] for t in r["toks"]]
        assert firsts == [t[0] for t in base["toks"]], \
            f"{label}: first decoded tokens diverged from kv_codec='none'"
        assert agreement(r["toks"]) >= 0.4, \
            f"{label}: token agreement collapsed"
    # equal-HBM-budget capacity: how many fully-backed slots one fixed
    # pool budget holds under each codec
    r = results["pallas_paged/cluster"]
    budget = 64 * r["pages_per_slot"] * r["page_fp"]   # 64 fp slots
    slots_fp = budget // (r["pages_per_slot"] * r["page_fp"])
    slots_cl = budget // (r["pages_per_slot"] * r["page_res"])
    assert slots_cl >= slots_fp * 1.3
    print(f"  equal HBM budget ({budget // 1024} KiB): {slots_fp} fp slots "
          f"-> {slots_cl} cluster slots "
          f"({r['page_fp'] / r['page_res']:.2f}x resident compression, "
          f"error bound {r['err']:.2e})")
    REPORT["kv_codec_compare"] = {
        label.replace("/", "_"): dict(
            tok_s=round(rr["tok_s"], 2),
            page_compression=round(rr["page_fp"] / rr["page_res"], 3),
            agreement=round(agreement(rr["toks"]), 4))
        for label, rr in results.items()}


# ---------------------------------------------------------------------------
# prefix sharing: shared-prefix trace replay, sharing on vs off
# ---------------------------------------------------------------------------

def prefix_share_compare(smoke: bool, seed: int = 0) -> None:
    """Replay the checked-in multi-tenant shared-prefix trace
    (benchmarks/traces/shared_prefix.jsonl: each tenant's prompts extend
    one deterministic 16-token system prefix) with ``prefix_share`` off
    vs on.  Sharing must be token-identical, and the accounting identity
    ``chunk_tokens(on) + tokens_reused == chunk_tokens(off)`` pins that
    every reused token is prefill work the off run actually paid for —
    the table reports the reuse, chunks avoided, copy-on-write copies,
    and mean time-to-first-token."""
    from repro.runtime import Scheduler, ServeEngine

    cfg, params = _reduced_lm()
    rows = [json.loads(line) for line in
            SHARED_PREFIX_TRACE.read_text().splitlines() if line.strip()]
    if smoke:
        rows = rows[:8]
    tenants = sorted({r["tenant"] for r in rows})
    prefixes = {t: np.random.default_rng(seed + 100 + i).integers(
        0, cfg.vocab_size, 16) for i, t in enumerate(tenants)}
    rng = np.random.default_rng(seed)
    reqs = []
    for r in rows:
        pre = prefixes[r["tenant"]]
        tail = rng.integers(0, cfg.vocab_size, r["prompt_len"] - len(pre))
        reqs.append((np.concatenate([pre, tail]), r["decode_len"]))
    slot_len = max(len(p) + g for p, g in reqs)
    chunk = 4
    print(f"\nprefix sharing: {len(reqs)} requests, {len(tenants)} tenants "
          f"(16-token shared prefixes), chunk {chunk}, page 8, batch 2, "
          f"reduced minitron-8b  [shared_prefix.jsonl]")
    print(f"{'sharing':>8} | {'tok/s':>7} | {'ttft':>7} | {'reused':>6} | "
          f"{'avoided':>7} | {'cow':>4}")

    results = {}
    for label, on in (("off", False), ("on", True)):
        engine = ServeEngine(cfg, params, compress=True)
        # kv_pages: headroom beyond the 2-slot worst case — a pool sized
        # exactly to the slots would evict every cached prefix at each
        # admission's reservation (the index lives in the spare pages)
        sched = Scheduler(engine, batch_size=2, slot_len=slot_len,
                          buckets=(64,), kv_page_size=8, kv_pages=20,
                          prefill_chunk=chunk, prefix_share=on)
        sched.submit(reqs[0][0], 2)              # warmup compile
        sched.run()
        if on:
            sched._pool.prefix.clear()           # cold index for the run
        engine.metrics = type(engine.metrics)()
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        done = sched.run()
        assert len(done) == len(reqs)
        m = engine.metrics
        ttfts = [r.first_token_latency() for r in
                 sorted(done, key=lambda r: r.rid)[-len(reqs):]]
        results[label] = dict(
            toks=tuple(tuple(r.generated) for r in
                       sorted(done, key=lambda r: r.rid)[-len(reqs):]),
            tok_s=m.tokens_per_s(),
            ttft=float(np.mean([t for t in ttfts if t is not None])),
            chunk_tokens=m.prefill_chunk_tokens,
            reused=m.prefix_tokens_reused,
            avoided=m.prefill_chunks_avoided,
            cow=m.prefix_cow_copies)
        r = results[label]
        print(f"{label:>8} | {r['tok_s']:>7.1f} | "
              f"{r['ttft'] * 1000:>5.0f}ms | {r['reused']:>6} | "
              f"{r['avoided']:>7} | {r['cow']:>4}")

    off, on = results["off"], results["on"]
    assert on["toks"] == off["toks"], \
        "prefix sharing changed generated tokens"
    assert on["reused"] > 0, "shared-prefix trace produced no reuse"
    assert [t[0] for t in on["toks"]] == [t[0] for t in off["toks"]]
    assert on["chunk_tokens"] + on["reused"] == off["chunk_tokens"], \
        "reused tokens do not account for the skipped prefill work"
    print(f"  {on['reused']} prompt tokens served from cached pages "
          f"({on['avoided']} chunks avoided, {on['cow']} copy-on-write "
          f"copies); token-identical outputs")
    REPORT["prefix_share_compare"] = {
        label: dict(tok_s=round(results[label]["tok_s"], 2),
                    ttft_ms=round(results[label]["ttft"] * 1000, 1),
                    tokens_reused=results[label]["reused"],
                    cow_copies=results[label]["cow"])
        for label in ("off", "on")}


# ---------------------------------------------------------------------------
# speculative decoding: ngram drafter vs plain decode on a repetitive trace
# ---------------------------------------------------------------------------

def speculative_compare(smoke: bool, seed: int = 0) -> None:
    """Speculative decoding (``speculate="ngram"``) vs plain decode on the
    checked-in repetition-heavy trace (benchmarks/traces/repetition.jsonl:
    short tiled prompts, long decode budgets).  Greedy verification makes
    speculation token-identical by construction — asserted per cell — so
    the whole comparison is about decode steps: every accepted draft token
    is one verify row instead of one full scheduler iteration.  The drafter
    pays off exactly when the token stream is predictable (here: tiled
    prompts steer the reduced model into its argmax attractor cycles,
    which the n-gram matcher then predicts), which is the workload the
    trace encodes; on incompressible streams acceptance drops and ``off``
    wins, hence the dedicated trace rather than the random mixes the other
    sections use.  The deterministic invariant (fewer decode steps, drafts
    accepted) is asserted everywhere; the wall-clock >= 1.2x tokens/s
    claim only on the full run at the default seed, on the single-phase
    ``pallas_paged`` cell where verification rides the same ragged
    mixed-step invocation as plain decode.  The model is the reduced
    minitron at ``vocab_size=8`` — narrow enough that greedy decode
    settles into its argmax attractor cycles (the predictable-stream
    regime speculation targets) instead of the near-random wander of the
    128-token vocabulary the other sections use."""
    from repro.runtime import Scheduler, ServeEngine

    cfg, params = _reduced_lm(vocab_size=8)
    rng = np.random.default_rng(seed)
    trace = load_trace_file(REPETITION_TRACE)
    rows = trace.requests[:4] if smoke else trace.requests
    reqs = []
    for r in rows:
        pat = rng.integers(0, cfg.vocab_size, 3)
        reps = -(-r.prompt_len // len(pat))          # ceil division
        prompt = np.tile(pat, reps)[:r.prompt_len]
        reqs.append((prompt, max(6, r.gen // 8) if smoke else r.gen))
    slot_len = max(len(p) + g for p, g in reqs)
    print(f"\nspeculative decoding: {len(reqs)} requests "
          f"(decode {min(g for _, g in reqs)}..{max(g for _, g in reqs)}), "
          f"batch 2, draft k=4, reduced minitron-8b  [repetition.jsonl]")
    print(f"{'backend/codec':>20} | {'spec':>5} | {'tok/s':>7} | "
          f"{'steps':>5} | {'accept':>6} | {'steps/tok':>9}")

    cells = {
        "gathered/none": dict(attn_backend="gathered", kv_page_size=4),
        "pallas_paged/none": dict(attn_backend="pallas_paged",
                                  kv_page_size=4, prefill_chunk=4),
        "pallas_paged/cluster": dict(attn_backend="pallas_paged",
                                     kv_page_size=4, prefill_chunk=4,
                                     kv_codec="cluster"),
    }
    reps_n = 1 if smoke else 3
    results = {}
    for label, kw in cells.items():
        for spec in ("off", "ngram"):
            engine = ServeEngine(cfg, params, compress=True)
            sched = Scheduler(engine, batch_size=2, slot_len=slot_len,
                              buckets=(128,), speculate=spec, draft_k=4,
                              **kw)
            sched.submit(reqs[0][0], 2)              # warmup compile
            sched.run()
            best = None
            for _ in range(reps_n):                  # best-of-N de-noises
                engine.metrics = type(engine.metrics)()
                for prompt, gen in reqs:
                    sched.submit(prompt, gen)
                done = sched.run()
                assert len(done) == len(reqs)
                m = engine.metrics
                total = sum(len(r.generated) for r in done)
                rep = dict(
                    toks=tuple(tuple(r.generated) for r in
                               sorted(done, key=lambda r: r.rid)
                               [-len(reqs):]),
                    tok_s=m.tokens_per_s(), steps=m.decode_steps,
                    accept=m.spec_acceptance_rate(),
                    spt=m.decode_steps / max(total, 1))
                if best is None or rep["tok_s"] > best["tok_s"]:
                    best = rep
            results[label, spec] = best
            print(f"{label:>20} | {spec:>5} | {best['tok_s']:>7.1f} | "
                  f"{best['steps']:>5} | {best['accept'] * 100:>5.0f}% | "
                  f"{best['spt']:>9.2f}")

    for label in cells:
        off, ngram = results[label, "off"], results[label, "ngram"]
        # greedy verification is the oracle: every emitted token is the
        # model's own argmax, so outputs must match token for token
        assert ngram["toks"] == off["toks"], \
            f"{label}: speculation changed generated tokens"
        assert ngram["accept"] > 0, f"{label}: no draft tokens accepted"
        # deterministic (no timing): accepted drafts collapse scheduler
        # iterations, and amortise to < 1 verify step per emitted token
        assert ngram["steps"] < off["steps"], \
            f"{label}: speculation did not reduce decode steps"
        assert ngram["spt"] < 1.0, \
            f"{label}: {ngram['spt']:.2f} verify steps per token"
    off = results["pallas_paged/none", "off"]
    ngram = results["pallas_paged/none", "ngram"]
    speedup = ngram["tok_s"] / max(off["tok_s"], 1e-9)
    print(f"  pallas_paged/none ngram/off tokens/s: {speedup:.2f}x at "
          f"{ngram['accept'] * 100:.0f}% acceptance "
          f"({ngram['spt']:.2f} steps/token; token-identical outputs)")
    REPORT["speculative"] = {
        label.replace("/", "_"): dict(
            tok_s_off=round(results[label, "off"]["tok_s"], 2),
            tok_s_ngram=round(results[label, "ngram"]["tok_s"], 2),
            acceptance=round(results[label, "ngram"]["accept"], 4),
            steps_per_token=round(results[label, "ngram"]["spt"], 4))
        for label in cells}
    REPORT["speculative"]["speedup_pallas_none"] = round(speedup, 3)
    # wall-clock claim, gated like trace_replay's skew invariant: full
    # run, default seed (smoke decode budgets are too small to amortise
    # the drafter's host work)
    if not smoke and seed == 0:
        assert speedup >= 1.2, \
            f"ngram speculation {speedup:.2f}x < 1.2x on repetition trace"


# ---------------------------------------------------------------------------
# telemetry: lifecycle trace + Prometheus export on the real scheduler
# ---------------------------------------------------------------------------

def telemetry_smoke(smoke: bool, seed: int = 0, trace_out=None,
                    metrics_out=None) -> None:
    """Serve a small mix with tracing on and validate the whole
    observability surface: the Chrome-trace JSON loads and carries
    exactly one admitted/retired pair per completed request, the
    Prometheus text parses, counters are monotone across scrapes, and
    tokens are identical to a telemetry-off run (telemetry must
    observe, never steer).  With ``trace_out`` / ``metrics_out`` set
    the artifacts are also written to disk (the CI smoke job does, and
    re-validates the files)."""
    from repro.runtime import Scheduler, ServeEngine, Telemetry, parse_prom

    cfg, params = _reduced_lm()
    rng = np.random.default_rng(seed)
    n = 5 if smoke else 10
    reqs = [(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16))),
             int(rng.integers(3, 9))) for _ in range(n)]
    print(f"\ntelemetry: {n} requests, batch 2, chunked prefill, "
          f"reduced minitron-8b")

    outs = {}
    for label, tel in (("off", None), ("on", Telemetry(trace=True))):
        engine = ServeEngine(cfg, params, compress=True, telemetry=tel)
        sched = Scheduler(engine, batch_size=2, buckets=(32,),
                          prefill_chunk=4, kv_page_size=8)
        for prompt, gen in reqs:
            sched.submit(prompt, gen)
        done = sched.run()
        assert len(done) == n
        outs[label] = tuple(tuple(r.generated)
                            for r in sorted(done, key=lambda r: r.rid))
        if tel is None:
            continue
        # scrape twice around extra work: every counter must be monotone
        prom1 = parse_prom(engine.render_prom())
        engine.cache.get(("nope",))          # one more miss
        prom2 = parse_prom(engine.render_prom())
        for key, v1 in prom1.items():
            name = key[0]
            if name.endswith(("_total", "_count", "_bucket", "_sum")):
                assert prom2[key] >= v1, f"counter {key} went backwards"
        chrome = tel.tracer.chrome()
        counts: dict = {}
        for e in chrome["traceEvents"]:
            counts[e["name"]] = counts.get(e["name"], 0) + 1
        assert counts.get("admitted") == counts.get("retired") == n, \
            f"admitted/retired spans != {n}: {counts}"
        assert counts.get("request") == n
        if trace_out:
            tel.tracer.write_chrome(trace_out)
            with open(trace_out) as f:
                loaded = json.load(f)
            assert len(loaded["traceEvents"]) == len(chrome["traceEvents"])
            print(f"  trace -> {trace_out} "
                  f"({len(loaded['traceEvents'])} events)")
        if metrics_out:
            text = engine.render_prom()
            parse_prom(text)
            with open(metrics_out, "w") as f:
                f.write(text)
            print(f"  metrics -> {metrics_out} "
                  f"({len(text.splitlines())} lines)")
        print(f"  {counts['request']} request span trees, "
              f"{len(prom2)} prometheus samples, counters monotone")
    assert outs["on"] == outs["off"], "telemetry changed generated tokens"
    print("  telemetry on/off token-identical")


# ---------------------------------------------------------------------------
# slot-level continuous batching vs wave mode on the real scheduler
# ---------------------------------------------------------------------------

def slot_vs_wave(smoke: bool, seed: int = 0) -> None:
    from repro.runtime import Scheduler, ServeEngine

    cfg, params = _reduced_lm()
    batch = 4
    prompt_len = 8                           # fixed: one prefill compile,
    rng = np.random.default_rng(seed)        # hit by every admission
    trace = bursty_trace(rng, n_requests=10 if smoke else 24,
                         gen_lo=2 if smoke else 8,
                         gen_hi=12 if smoke else 48)
    reqs = [(rng.integers(0, cfg.vocab_size, prompt_len), r.gen)
            for r in trace.requests]
    slot_len = prompt_len + max(g for _, g in reqs)  # shared decode shape
    print(f"\nslot batching vs wave mode: {len(reqs)} requests "
          f"(gen {min(g for _, g in reqs)}..{max(g for _, g in reqs)}), "
          f"batch {batch}, reduced minitron-8b")

    # continuous runs FIRST so one-time process warmup (XLA autotuning
    # etc.) can only help wave-mode; best-of-3 reps de-noises the tiny
    # decode totals of the reduced model
    results = {}
    for mode in ("continuous", "wave"):
        engine = ServeEngine(cfg, params, compress=True)
        sched = Scheduler(engine, batch_size=batch, mode=mode,
                          slot_len=slot_len)
        sched.submit(reqs[0][0], 2)          # warmup: compile prefill at
        sched.run()                          # prompt_len + decode at (S, L)
        best = None
        for _ in range(3):
            engine.metrics = type(engine.metrics)()
            for prompt, gen in reqs:
                sched.submit(prompt, gen)
            done = sched.run()
            m = engine.metrics
            assert len(done) == len(reqs)
            rep = (m.tokens_per_s(), m.occupancy(), m.decode_steps,
                   tuple(tuple(r.generated) for r in
                         sorted(done, key=lambda r: r.rid)[-len(reqs):]))
            if best is None or rep[0] > best[0]:
                best = rep
        results[mode] = best
        print(f"  {mode:>10}: {best[0]:7.1f} tok/s | "
              f"occupancy {best[1] * 100:3.0f}% | "
              f"{best[2]} decode steps")
    assert results["wave"][3] == results["continuous"][3], \
        "scheduling mode changed generated tokens"
    # deterministic invariants (step counts and occupancy don't depend on
    # machine timing): admit-on-retire must strictly reduce decode steps
    assert results["continuous"][2] < results["wave"][2], \
        "continuous batching did not reduce decode steps"
    assert results["continuous"][1] > results["wave"][1], \
        "continuous batching did not raise occupancy"
    speedup = results["continuous"][0] / max(results["wave"][0], 1e-9)
    print(f"  continuous/wave tokens/s: {speedup:.2f}x "
          f"(token-identical outputs)")
    REPORT["slot_vs_wave"] = {
        mode: dict(tok_s=round(results[mode][0], 2),
                   occupancy=round(results[mode][1], 4),
                   decode_steps=results[mode][2])
        for mode in ("continuous", "wave")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--trace", choices=["bursty"], default=None,
                    help="replay a synthetic arrival trace through every "
                         "eviction policy + compare scheduler modes")
    ap.add_argument("--trace-file", type=str, default=None,
                    help="replay a recorded JSONL trace (arrival_time, "
                         "prompt_len, decode_len, tenant per line) through "
                         "every eviction policy; see benchmarks/traces/"
                         "sample.jsonl")
    ap.add_argument("--trace-time-step", type=float, default=0.05,
                    help="seconds of recorded arrival time per scheduler "
                         "admission step (trace-file replay)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run: synthetic + sample-file trace "
                         "replay (all policies), slot-vs-wave, chunked "
                         "prefill, and the attention-backend comparison")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the synthetic trace generators "
                         "(bursty arrivals + scheduler request mixes), so "
                         "replays are reproducible run-to-run; the "
                         "freq-vs-LRU CI invariant is only asserted on "
                         "the default seed")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep a fine capacity grid over the replayed "
                         "trace (synthetic bursty, or --trace-file) and "
                         "print the recommended decode_cache capacity at "
                         "the hit-rate-cliff knee")
    ap.add_argument("--autotune-policy", choices=list(POLICY_NAMES),
                    default="freq",
                    help="eviction policy the autotune sweep measures")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write the telemetry section's Chrome-trace JSON "
                         "here (CI validates it re-loads)")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the telemetry section's Prometheus text "
                         "exposition here (CI validates it re-parses)")
    ap.add_argument("--out", type=str, default=None,
                    help="write each section's headline numbers (tokens/s, "
                         "TTFT, hit/acceptance rates, compression "
                         "multipliers) as one JSON report — the checked-in "
                         "BENCH_serve.json is generated this way")
    args = ap.parse_args()

    if args.autotune:
        if args.trace_file:
            trace = load_trace_file(args.trace_file,
                                    time_step=args.trace_time_step)
        else:
            trace = bursty_trace(np.random.default_rng(args.seed),
                                 n_requests=24 if args.smoke else 64)
        autotune_capacity(trace, policy=args.autotune_policy)
        return
    if args.trace_file:
        trace = load_trace_file(args.trace_file,
                                time_step=args.trace_time_step)
        trace_replay(smoke=args.smoke, trace=trace,
                     label=pathlib.Path(args.trace_file).name)
        if not (args.trace or args.smoke):
            return
    if args.trace or args.smoke:
        trace_replay(smoke=args.smoke, seed=args.seed)
        if args.smoke:
            print()
            trace_replay(smoke=True,
                         trace=load_trace_file(SAMPLE_TRACE),
                         label="sample.jsonl")
        slot_vs_wave(smoke=args.smoke, seed=args.seed)
        prefill_compare(smoke=args.smoke, seed=args.seed)
        backend_compare(smoke=args.smoke, seed=args.seed)
        kv_codec_compare(smoke=args.smoke, seed=args.seed)
        prefix_share_compare(smoke=args.smoke, seed=args.seed)
        speculative_compare(smoke=args.smoke, seed=args.seed)
        telemetry_smoke(smoke=args.smoke, seed=args.seed,
                        trace_out=args.trace_out,
                        metrics_out=args.metrics_out)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(REPORT, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"\nheadline numbers ({len(REPORT)} sections) -> "
                  f"{args.out}")
        return
    capacity_sweep(args.steps)


if __name__ == "__main__":
    main()
